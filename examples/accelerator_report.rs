//! Regenerate every table and figure of the paper's evaluation section
//! (Tables III–V, Figs. 11–12, §V.A) with our simulated/measured values
//! next to the paper's reported ones.
//!
//! Run: `cargo run --release --example accelerator_report`

use swin_fpga::baseline::live;
use swin_fpga::report;

fn main() {
    println!("{}", report::table3_submodules());
    println!("{}", report::table4_accelerators());
    println!("{}", report::table5_comparison());
    println!("{}", report::fig11_speedup());
    println!("{}", report::fig12_energy());
    println!("{}", report::sec5a_invalid());

    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        match live::measure_live_cpu(&dir, 5) {
            Ok(s) => println!("{s}"),
            Err(e) => println!("(live CPU measurement skipped: {e})"),
        }
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the live CPU rows)");
    }
}
