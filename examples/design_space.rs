//! Design-space exploration (DESIGN.md §6 ablations):
//!
//! * MMU output-tile width c_o — the paper fixes c_o = 32 (= head dim);
//!   the sweep shows the invalid-computation / utilisation trade-off
//!   behind that choice (Eq. 17 generalised);
//! * PE array size — DSP budget vs FPS (why 32×49 saturates the device);
//! * DDR efficiency — sensitivity of the memory-bound operating point;
//! * nonlinear-unit overlap — what serialising the SCU/GCU would cost;
//! * cross-unit weight prefetch — what the pipeline IR's inter-unit
//!   double buffering buys over sequential scheduling units;
//! * nonlinear-unit **design space** — the trait-backed SCU/GCU variants
//!   (baseline / QUARK / PEANO) swept over accuracy × cycles × power,
//!   with the per-variant Pareto front written to PARETO_nonlinear.json.
//!
//! Run: `cargo run --release --example design_space`
//! (`SWIN_BENCH_SHORT=1` skips the slow fleet sections — CI smoke mode.)

use std::collections::BTreeMap;

use swin_fpga::accel::nonlinear::NlDesign;
use swin_fpga::accel::power::{accelerator_power_w, energy_efficiency, Activity};
use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::trace::{Timeline, Unit};
use swin_fpga::accel::AccelConfig;
use swin_fpga::approx::error::{gelu_stats_for, softmax_stats_for};
use swin_fpga::model::config::TINY;
use swin_fpga::model::flops::invalid_fraction_block_with_co;
use swin_fpga::report::Table;
use swin_fpga::server::router::{
    fleet_capacity_fps, fleet_percentiles, hetero_ts_fleet, percentile, LoadModel, Policy,
    Router,
};
use swin_fpga::server::workload::{classed_arrivals, Arrival};
use swin_fpga::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// One (design × variant) sweep point: the three Pareto axes plus the
/// context columns the table prints.
struct DesignPoint {
    design: NlDesign,
    variant: &'static str,
    softmax_max_err: f64,
    gelu_max_abs: f64,
    cycles: u64,
    fps: f64,
    power_w: f64,
}

impl DesignPoint {
    /// Accuracy axis: worst error across both units (lower = better).
    fn err(&self) -> f64 {
        self.softmax_max_err.max(self.gelu_max_abs)
    }

    /// Pareto dominance on (accuracy, cycles, power): no worse on every
    /// axis and strictly better on at least one.
    fn dominates(&self, o: &DesignPoint) -> bool {
        let le = self.err() <= o.err() && self.cycles <= o.cycles && self.power_w <= o.power_w;
        let lt = self.err() < o.err() || self.cycles < o.cycles || self.power_w < o.power_w;
        le && lt
    }
}

fn main() {
    // --- c_o sweep -------------------------------------------------------
    let mut t = Table::new(
        "MMU output-tile width c_o (Swin-T block, Eq. 17 generalised)",
        &["c_o", "invalid U", "K^T pad cols", "note"],
    );
    for co in [8usize, 16, 32, 64] {
        let u = invalid_fraction_block_with_co(96, 7, co);
        let pad = 49usize.div_ceil(co) * co - 49;
        t.row(&[
            co.to_string(),
            format!("{:.2}%", u * 100.0),
            pad.to_string(),
            if co == 32 { "paper (= head dim)".into() } else { String::new() },
        ]);
    }
    println!("{t}");

    // --- PE array sweep ---------------------------------------------------
    let mut t = Table::new(
        "PE array size (Swin-T, 200 MHz)",
        &["PEs", "DSPs", "FPS", "MMU util", "bound"],
    );
    for pes in [8usize, 16, 32, 64, 128] {
        let mut cfg = AccelConfig::paper();
        cfg.mmu_pes = pes;
        let r = Simulator::new(&TINY, cfg).simulate_inference();
        t.row(&[
            pes.to_string(),
            (pes * 49).to_string(),
            format!("{:.1}", r.fps()),
            format!("{:.1}%", r.mmu_utilization() * 100.0),
            if r.memory_bound() { "memory".into() } else { "compute".into() },
        ]);
    }
    println!("{t}");

    // --- DDR efficiency sweep ----------------------------------------------
    let mut t = Table::new(
        "DDR efficiency sensitivity (Swin-T)",
        &["efficiency", "GB/s", "FPS"],
    );
    for eff in [0.6, 0.7, 0.8, 0.88, 0.95, 1.0] {
        let mut cfg = AccelConfig::paper();
        cfg.mem_efficiency = eff;
        let gbps = cfg.effective_bw() * cfg.freq_mhz * 1e6 / 1e9;
        let r = Simulator::new(&TINY, cfg).simulate_inference();
        t.row(&[
            format!("{eff:.2}"),
            format!("{gbps:.2}"),
            format!("{:.1}", r.fps()),
        ]);
    }
    println!("{t}");

    // --- nonlinear overlap ablation ----------------------------------------
    let mut t = Table::new(
        "SCU/GCU overlap ablation (all variants)",
        &["model", "FPS overlapped", "FPS serialised", "cost"],
    );
    for v in swin_fpga::report::paper_variants() {
        let mut cfg = AccelConfig::paper();
        cfg.overlap_nonlinear = true;
        let a = Simulator::new(v, cfg.clone()).simulate_inference().fps();
        cfg.overlap_nonlinear = false;
        let b = Simulator::new(v, cfg).simulate_inference().fps();
        t.row(&[
            v.name.to_string(),
            format!("{a:.1}"),
            format!("{b:.1}"),
            format!("{:.1}%", (a - b) / a * 100.0),
        ]);
    }
    println!("{t}");

    // --- inter-unit prefetch ablation ----------------------------------------
    let mut t = Table::new(
        "cross-unit weight prefetch ablation (pipeline IR, all variants)",
        &["model", "FPS pipelined", "FPS sequential", "gain"],
    );
    for v in swin_fpga::report::paper_variants() {
        let a = Simulator::new(v, AccelConfig::paper()).simulate_inference().fps();
        let b = Simulator::new(v, AccelConfig::paper().sequential())
            .simulate_inference()
            .fps();
        t.row(&[
            v.name.to_string(),
            format!("{a:.1}"),
            format!("{b:.1}"),
            format!("{:+.1}%", (a - b) / b * 100.0),
        ]);
    }
    println!("{t}");

    // --- nonlinear-unit design space: accuracy × cycles × power -------------
    // error stats are variant-independent (fixed kernels), measured once
    // through the same harness the golden tests pin
    let design_errs: Vec<(NlDesign, f64, f64)> = NlDesign::ALL
        .into_iter()
        .map(|d| {
            let nd = d.design();
            let s = softmax_stats_for(
                |row, out| out.copy_from_slice(&nd.softmax(row, row.len())),
                100,
                49,
                3.0,
                9,
            );
            let g = gelu_stats_for(|q| nd.gelu(&[q])[0], -4.0, 4.0, 0.01);
            (d, s.max_err, g.max_abs)
        })
        .collect();
    let mut t = Table::new(
        "nonlinear-unit designs (paper config, per variant)",
        &["model", "design", "softmax err", "gelu err", "cycles", "FPS", "W", "FPS/W"],
    );
    let mut points: Vec<DesignPoint> = Vec::new();
    for v in swin_fpga::report::paper_variants() {
        for &(d, smax, gmax) in &design_errs {
            let cfg = AccelConfig::paper().nonlinear(d);
            let r = Simulator::new(v, cfg.clone()).simulate_inference();
            let p = accelerator_power_w(v, &cfg, &r, Activity::from_sim(&r));
            t.row(&[
                v.name.to_string(),
                d.name().to_string(),
                format!("{smax:.4}"),
                format!("{gmax:.4}"),
                r.total_cycles.to_string(),
                format!("{:.2}", r.fps()),
                format!("{p:.3}"),
                format!("{:.3}", energy_efficiency(r.fps(), p)),
            ]);
            points.push(DesignPoint {
                design: d,
                variant: v.name,
                softmax_max_err: smax,
                gelu_max_abs: gmax,
                cycles: r.total_cycles,
                fps: r.fps(),
                power_w: p,
            });
        }
    }
    println!("{t}");

    // per-variant Pareto front on (accuracy, cycles, power)
    let mut front_json: Vec<Json> = Vec::new();
    println!("== Pareto front per variant (accuracy x cycles x power) ==");
    for v in swin_fpga::report::paper_variants() {
        let vs: Vec<&DesignPoint> = points.iter().filter(|p| p.variant == v.name).collect();
        let front: Vec<&&DesignPoint> = vs
            .iter()
            .filter(|p| !vs.iter().any(|q| q.dominates(p)))
            .collect();
        let names: Vec<&str> = front.iter().map(|p| p.design.name()).collect();
        println!("  {:<8} {}", v.name, names.join(", "));
        front_json.push(obj(vec![
            ("variant", Json::Str(v.name.into())),
            (
                "front",
                Json::Arr(names.iter().map(|n| Json::Str((*n).into())).collect()),
            ),
        ]));
    }
    let json = obj(vec![
        ("sweep", Json::Str("nonlinear_design_space".into())),
        (
            "provenance",
            Json::Str("native (cargo run --release --example design_space)".into()),
        ),
        ("axes", Json::Arr(vec![
            Json::Str("max_err (softmax ∪ gelu, lower better)".into()),
            Json::Str("total_cycles".into()),
            Json::Str("power_w".into()),
        ])),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("variant", Json::Str(p.variant.into())),
                            ("design", Json::Str(p.design.name().into())),
                            ("softmax_max_err", Json::Num(p.softmax_max_err)),
                            ("gelu_max_abs", Json::Num(p.gelu_max_abs)),
                            ("cycles", Json::Num(p.cycles as f64)),
                            ("fps", Json::Num(p.fps)),
                            ("power_w", Json::Num(p.power_w)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("pareto_front", Json::Arr(front_json)),
    ]);
    let path = "PARETO_nonlinear.json";
    std::fs::write(path, format!("{json}\n")).expect("write PARETO_nonlinear.json");
    println!("  wrote {path}\n");

    // --- unit-utilisation timeline + Chrome-trace export --------------------
    let tl = Timeline::capture(&TINY, AccelConfig::paper());
    println!("== unit utilisation over one Swin-T inference ==");
    for u in [Unit::Mmu, Unit::Mru, Unit::Scu, Unit::Gcu] {
        println!(
            "  {:<8} {:>6.1}%  ({} busy cycles)",
            u.name(),
            tl.utilisation(u) * 100.0,
            tl.busy(u)
        );
    }
    let trace_path = "artifacts/swin_t_timeline.trace.json";
    if std::fs::write(trace_path, tl.to_chrome_trace()).is_ok() {
        println!("  chrome trace written to {trace_path} (open in Perfetto)\n");
    }

    // --- fleet sections: skipped in CI smoke mode ----------------------------
    if std::env::var("SWIN_BENCH_SHORT").is_ok() {
        println!("SWIN_BENCH_SHORT set: skipping fleet sweeps");
        return;
    }

    // --- multi-card fleet: latency vs offered load ---------------------------
    let mut t = Table::new(
        "fleet scale-out (simulated swin-t cards, least-loaded routing)",
        &["cards", "offered FPS", "p50 ms", "p99 ms"],
    );
    for cards in [1usize, 2, 4] {
        for rate in [30.0, 80.0, 150.0] {
            let mut r = Router::new(cards, &TINY, AccelConfig::paper(), Policy::LeastLoaded);
            let lats = r.run_poisson(400, rate, 29);
            t.row(&[
                cards.to_string(),
                format!("{rate:.0}"),
                format!("{:.1}", percentile(&lats, 0.5)),
                format!("{:.1}", percentile(&lats, 0.99)),
            ]);
        }
    }
    println!("{t}");

    // --- per-card batcher fleet: load-signal ablation -------------------------
    // heterogeneous swin-t/s fleet, bursty mixed-SLO arrivals: JSQ on
    // modelled backlog (decompose + service_estimate) vs raw busy horizon
    let mut t = Table::new(
        "queued fleet ablation (2x swin-t + 2x swin-s, bursty, 50% interactive)",
        &["load signal", "p50 ms", "p99 ms", "interactive p99", "batch p99"],
    );
    let hetero = || hetero_ts_fleet(&AccelConfig::paper());
    let cap = fleet_capacity_fps(&hetero());
    let arr = classed_arrivals(
        Arrival::Bursty { high: 2.0 * cap, burst_s: 0.2, gap_s: 0.3 },
        500,
        0.5,
        31,
    );
    for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
        let mut r = Router::from_engines(hetero(), Policy::LeastLoaded).with_load(load);
        let comps = r.run_classed(&arr);
        let [p50, p99, inter_p99, batch_p99] = fleet_percentiles(&comps);
        t.row(&[
            load.name().into(),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
            format!("{inter_p99:.1}"),
            format!("{batch_p99:.1}"),
        ]);
    }
    println!("{t}");
}
