use std::time::Instant;
use swin_fpga::accel::functional::FunctionalModel;
use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{MICRO, TINY};
use swin_fpga::model::weights::WeightStore;
use swin_fpga::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let sim = Simulator::new(&TINY, AccelConfig::paper());
    let t0 = Instant::now();
    for _ in 0..100 { std::hint::black_box(sim.simulate_inference()); }
    println!("simulate_inference swin-t: {:?}/call", t0.elapsed() / 100);

    let dir = std::path::PathBuf::from("artifacts");
    let ws = WeightStore::load(&dir.join("weights_micro.bin"), &dir.join("weights_micro_manifest.json"))?;
    let model = FunctionalModel::new(&MICRO, &ws, AccelConfig::paper());
    let mut rng = Rng::new(0);
    let img: Vec<f32> = (0..56*56*3).map(|_| rng.range_f32(0.0, 1.0)).collect();
    let t0 = Instant::now();
    for _ in 0..10 { std::hint::black_box(model.run_image(&img)?); }
    println!("functional run_image (micro): {:?}/call", t0.elapsed() / 10);
    Ok(())
}
