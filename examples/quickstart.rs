//! Quickstart: the three things this library does, in 60 lines.
//!
//! 1. simulate the paper's accelerator for Swin-T (Table V numbers);
//! 2. run a real image through the AOT-compiled swin-micro model via
//!    PJRT (no Python on this path);
//! 3. cross-check the Rust functional datapath against the AOT artifact
//!    bit-for-bit.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::path::PathBuf;

use swin_fpga::accel::functional::FunctionalModel;
use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{MICRO, TINY};
use swin_fpga::model::weights::WeightStore;
use swin_fpga::report;
use swin_fpga::runtime::{Runtime, Tensor};
use swin_fpga::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. cycle-level simulation of the paper's deployment ------------
    let sim = Simulator::new(&TINY, AccelConfig::paper());
    let r = sim.simulate_inference();
    println!("{}", report::render_sim_result(&TINY, &r));

    // --- 2. serve one image through the AOT artifact --------------------
    let dir = PathBuf::from("artifacts");
    let rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let eng = rt.engine("swin_micro_float_b1.hlo.txt")?;
    let mut rng = Rng::new(0);
    let img: Vec<f32> = (0..56 * 56 * 3).map(|_| rng.range_f32(0.0, 1.0)).collect();
    let logits = eng.run(&[Tensor::F32(img.clone())])?;
    let logits = logits.as_f32()?.to_vec();
    let top = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!("float artifact: class {} (logit {:.4})", top.0, top.1);

    // --- 3. bit-exact cross-check: Rust datapath vs AOT fixed model -----
    let ws = WeightStore::load(
        &dir.join("weights_micro.bin"),
        &dir.join("weights_micro_manifest.json"),
    )?;
    let model = FunctionalModel::new(&MICRO, &ws, AccelConfig::paper());
    let ours = model.run_image(&img)?;
    let aot = rt
        .engine("swin_micro_fixed_b1.hlo.txt")?
        .run(&[Tensor::F32(img)])?;
    assert_eq!(aot.as_i32()?, ours.as_slice());
    println!(
        "fixed-point logits (Q7.8): {:?}",
        ours.iter().map(|&q| q as f32 / 256.0).collect::<Vec<_>>()
    );
    println!("functional simulator ↔ AOT Pallas artifact: bit-exact ✓");
    Ok(())
}
