//! End-to-end serving driver (DESIGN.md's mandated e2e validation):
//! serve batched classification requests through the continuous batcher
//! and report latency/throughput/occupancy under several arrival rates
//! and batching policies.
//!
//! With `artifacts/` present (and a real PJRT runtime) this drives the
//! AOT swin-micro model; otherwise it falls back to the simulated
//! swin-micro card so the serving stack is exercised end-to-end either
//! way — same batcher, same `Engine` trait, different backend.
//!
//! Run: `cargo run --release --example serve_images`

use std::path::PathBuf;
use std::time::Duration;

use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::MICRO;
use swin_fpga::server::{
    run_demo_metrics, run_demo_metrics_sim, BatchMode, BatchPolicy, Metrics,
};

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    let use_pjrt = dir.join("manifest.json").exists();

    let demo = |total: usize, rate: f64, policy: BatchPolicy| -> anyhow::Result<Metrics> {
        if use_pjrt {
            match run_demo_metrics(&dir, total, rate, policy.clone()) {
                Ok(m) => return Ok(m),
                Err(e) => println!("(pjrt unavailable, using sim backend: {e:#})"),
            }
        }
        run_demo_metrics_sim(&MICRO, AccelConfig::paper(), 1.0, total, rate, policy)
    };

    println!("swin-micro serving demo — continuous batcher, buckets 1/2/4/8\n");

    // sweep arrival rate at the default batching policy
    for rate in [200.0, 1_000.0, 4_000.0] {
        let m = demo(48, rate, BatchPolicy::default())?;
        println!("arrival {rate:>6.0} req/s:\n{m}\n");
    }

    // batching ablation: cap the batcher at 1 (no batching) vs 8, and the
    // seed's stop-the-world flush cycle vs continuous admission
    println!("--- batching policy ablation (4000 req/s offered) ---");
    for max_batch in [1usize, 2, 4, 8] {
        let m = demo(
            48,
            4_000.0,
            BatchPolicy {
                max_batch,
                ..Default::default()
            },
        )?;
        println!(
            "max_batch {max_batch}: throughput {:>7.1} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms  occupancy {:>3.0}%",
            m.throughput(),
            m.percentile_ms(0.50),
            m.percentile_ms(0.99),
            m.occupancy_mean() * 100.0
        );
    }
    for mode in [BatchMode::Continuous, BatchMode::StopTheWorld] {
        let m = demo(
            48,
            4_000.0,
            BatchPolicy {
                mode,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
        )?;
        println!(
            "{:<14} throughput {:>7.1} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms",
            format!("{mode:?}"),
            m.throughput(),
            m.percentile_ms(0.50),
            m.percentile_ms(0.99)
        );
    }
    Ok(())
}
