//! End-to-end serving driver (DESIGN.md's mandated e2e validation):
//! load the AOT swin-micro model, serve batched classification requests
//! through the router/dynamic-batcher, and report latency/throughput
//! under several arrival rates and batching policies.
//!
//! Run: `make artifacts && cargo run --release --example serve_images`
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::path::PathBuf;

use swin_fpga::server::run_demo_metrics;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "run `make artifacts` first"
    );

    println!("swin-micro serving demo — PJRT CPU engines, batch sizes 1/2/4/8\n");

    // sweep arrival rate at the default batching policy
    for rate in [20.0, 60.0, 200.0] {
        let m = run_demo_metrics(&dir, 48, rate, 8)?;
        println!("arrival {rate:>6.0} req/s:\n{m}\n");
    }

    // batching ablation: cap the batcher at 1 (no batching) vs 8
    println!("--- batching policy ablation (200 req/s offered) ---");
    for max_batch in [1usize, 2, 4, 8] {
        let m = run_demo_metrics(&dir, 48, 200.0, max_batch)?;
        println!(
            "max_batch {max_batch}: throughput {:>7.1} req/s  p50 {:>8.2} ms  p99 {:>8.2} ms",
            m.throughput(),
            m.percentile_ms(0.50),
            m.percentile_ms(0.99)
        );
    }
    Ok(())
}
