"""AOT export: lower L2/L1 graphs to HLO *text* artifacts for the Rust runtime.

Interchange is HLO text, NOT `.serialize()` — jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all under artifacts/, gitignored, rebuilt by `make artifacts`):

  swin_micro_float_b{1,2,4,8}.hlo.txt   float serving model, weights baked
  swin_micro_fixed_b1.hlo.txt           full fixed-point datapath (Pallas
                                        MMU/SCU/GCU), bit-exact twin of the
                                        Rust simulator's functional model
  kernel_mmu.hlo.txt                    standalone L1 kernels at canonical
  kernel_softmax.hlo.txt                shapes, for the Rust-side bit-exact
  kernel_gelu.hlo.txt                   cross-check tests
  kernel_gelu_corrected.hlo.txt         ablation constant (DESIGN.md §6)
  weights_micro.bin / _manifest.json    quantised fused weights for the
                                        Rust simulator
  manifest.json                         index: entry shapes/dtypes per artifact

Weights are deterministic (PRNGKey 0/1) so Python and Rust agree without
shipping a checkpoint.  Run via `python -m compile.aot --out-dir ../artifacts`.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import fixedpoint as fp
from . import fusion, model
from .configs import MICRO, SwinConfig
from .kernels import gelu as gelu_k
from .kernels import mmu
from .kernels import softmax as softmax_k

BATCHES = (1, 2, 4, 8)

# Canonical kernel cross-check shapes (mirrored in rust/tests/cross_check.rs)
MMU_A_SHAPE = (49, 96)    # window rows x C_I
MMU_B_SHAPE = (96, 64)    # C_I x 2 tiles of c_o
SOFTMAX_SHAPE = (49, 64)  # one score matrix, padded 49 -> 64 lanes
SOFTMAX_VALID = 49
GELU_SHAPE = (49, 128)    # one window of FFN activations


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: baked weights must survive the text round-trip
    # (default printing elides them as `constant({...})`).
    return comp.as_hlo_text(print_large_constants=True)


def _write(out_dir: str, name: str, text: str) -> None:
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {name} ({len(text) / 1e6:.2f} MB)")


def build_params(cfg: SwinConfig):
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    params = model.randomize_bn_stats(params, jax.random.PRNGKey(1))
    fused = fusion.fuse_params(cfg, params)
    q = fusion.quantize_fused(cfg, fused)
    return params, fused, q


def export_micro(out_dir: str, manifest: dict) -> None:
    cfg = MICRO
    _, fused, q = build_params(cfg)

    for b in BATCHES:
        spec = jax.ShapeDtypeStruct((b, cfg.img_size, cfg.img_size, 3),
                                    jnp.float32)
        fn = functools.partial(model.forward_float, cfg, fused)
        lowered = jax.jit(lambda x: (fn(x),)).lower(spec)
        name = f"swin_micro_float_b{b}.hlo.txt"
        _write(out_dir, name, to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "kind": "swin_float", "variant": cfg.name, "batch": b,
            "input": {"shape": list(spec.shape), "dtype": "f32"},
            "output": {"shape": [b, cfg.num_classes], "dtype": "f32"},
        }

    spec = jax.ShapeDtypeStruct((1, cfg.img_size, cfg.img_size, 3),
                                jnp.float32)
    fnq = functools.partial(model.forward_fixed, cfg, q)
    lowered = jax.jit(lambda x: (fnq(x),)).lower(spec)
    name = "swin_micro_fixed_b1.hlo.txt"
    _write(out_dir, name, to_hlo_text(lowered))
    manifest["artifacts"][name] = {
        "kind": "swin_fixed", "variant": cfg.name, "batch": 1,
        "input": {"shape": list(spec.shape), "dtype": "f32"},
        "output": {"shape": [1, cfg.num_classes], "dtype": "i32",
                   "frac": fp.DATA_FRAC},
    }

    fusion.write_weights(
        q,
        os.path.join(out_dir, "weights_micro.bin"),
        os.path.join(out_dir, "weights_micro_manifest.json"),
    )
    print("  wrote weights_micro.bin + manifest")


def export_kernels(out_dir: str, manifest: dict) -> None:
    a_spec = jax.ShapeDtypeStruct(MMU_A_SHAPE, jnp.int32)
    b_spec = jax.ShapeDtypeStruct(MMU_B_SHAPE, jnp.int32)
    lowered = jax.jit(
        lambda a, b: (mmu.matmul_fixed(a, b, rshift=fp.WEIGHT_FRAC),)
    ).lower(a_spec, b_spec)
    _write(out_dir, "kernel_mmu.hlo.txt", to_hlo_text(lowered))
    manifest["artifacts"]["kernel_mmu.hlo.txt"] = {
        "kind": "kernel", "op": "mmu",
        "inputs": [{"shape": list(MMU_A_SHAPE), "dtype": "i32"},
                   {"shape": list(MMU_B_SHAPE), "dtype": "i32"}],
        "rshift": fp.WEIGHT_FRAC,
        "output": {"shape": [MMU_A_SHAPE[0], MMU_B_SHAPE[1]], "dtype": "i32"},
    }

    s_spec = jax.ShapeDtypeStruct(SOFTMAX_SHAPE, jnp.int32)
    lowered = jax.jit(
        lambda x: (softmax_k.softmax_rows(x, n_valid=SOFTMAX_VALID),)
    ).lower(s_spec)
    _write(out_dir, "kernel_softmax.hlo.txt", to_hlo_text(lowered))
    manifest["artifacts"]["kernel_softmax.hlo.txt"] = {
        "kind": "kernel", "op": "softmax", "n_valid": SOFTMAX_VALID,
        "inputs": [{"shape": list(SOFTMAX_SHAPE), "dtype": "i32"}],
        "output": {"shape": list(SOFTMAX_SHAPE), "dtype": "i32",
                   "frac": fp.PROB_FRAC},
    }

    g_spec = jax.ShapeDtypeStruct(GELU_SHAPE, jnp.int32)
    for corrected, name in ((False, "kernel_gelu.hlo.txt"),
                            (True, "kernel_gelu_corrected.hlo.txt")):
        lowered = jax.jit(
            functools.partial(
                lambda c, x: (gelu_k.gelu_rows(x, corrected=c),), corrected)
        ).lower(g_spec)
        _write(out_dir, name, to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "kind": "kernel", "op": "gelu", "corrected": corrected,
            "inputs": [{"shape": list(GELU_SHAPE), "dtype": "i32"}],
            "output": {"shape": list(GELU_SHAPE), "dtype": "i32",
                       "frac": fp.DATA_FRAC},
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest: dict = {
        "format": "hlo-text",
        "data_frac": fp.DATA_FRAC,
        "weight_frac": fp.WEIGHT_FRAC,
        "prob_frac": fp.PROB_FRAC,
        "artifacts": {},
    }
    print("exporting kernels...")
    export_kernels(args.out_dir, manifest)
    print("exporting swin-micro...")
    export_micro(args.out_dir, manifest)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
