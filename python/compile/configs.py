"""Swin Transformer variant configurations (paper Table/Section V).

`MICRO` is ours: a 2-stage Swin small enough that the *full fixed-point
datapath* (Pallas MMU/SCU/GCU kernels everywhere) AOT-compiles and runs in
seconds on the CPU PJRT client.  It preserves every structural feature of
the paper's workload: 4x4 patch embed as matmul, W-MSA and SW-MSA with
masks, patch merging, BN-instead-of-LN with the extra FFN BNs, head dim 32.

T/S/B match the paper: depths <2,2,6,2> / <2,2,18,2> / <2,2,18,2>,
C = 96/96/128, window M = 7, MLP ratio M_r = 4, 224x224 inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SwinConfig:
    name: str
    img_size: int
    patch_size: int
    in_chans: int
    embed_dim: int
    depths: Tuple[int, ...]
    num_heads: Tuple[int, ...]
    window: int
    mlp_ratio: int
    num_classes: int

    @property
    def num_stages(self) -> int:
        return len(self.depths)

    def stage_dim(self, s: int) -> int:
        return self.embed_dim * (1 << s)

    def stage_resolution(self, s: int) -> int:
        return self.img_size // self.patch_size // (1 << s)

    @property
    def head_dim(self) -> int:
        # Paper §IV.B: every head has dimension 32 (why c_o = 32).
        d = self.embed_dim // self.num_heads[0]
        return d

    @property
    def final_dim(self) -> int:
        return self.stage_dim(self.num_stages - 1)


MICRO = SwinConfig(
    name="swin-micro", img_size=56, patch_size=4, in_chans=3,
    embed_dim=32, depths=(2, 2), num_heads=(1, 2), window=7,
    mlp_ratio=4, num_classes=10,
)

TINY = SwinConfig(
    name="swin-t", img_size=224, patch_size=4, in_chans=3,
    embed_dim=96, depths=(2, 2, 6, 2), num_heads=(3, 6, 12, 24), window=7,
    mlp_ratio=4, num_classes=1000,
)

SMALL = SwinConfig(
    name="swin-s", img_size=224, patch_size=4, in_chans=3,
    embed_dim=96, depths=(2, 2, 18, 2), num_heads=(3, 6, 12, 24), window=7,
    mlp_ratio=4, num_classes=1000,
)

BASE = SwinConfig(
    name="swin-b", img_size=224, patch_size=4, in_chans=3,
    embed_dim=128, depths=(2, 2, 18, 2), num_heads=(4, 8, 16, 32), window=7,
    mlp_ratio=4, num_classes=1000,
)

VARIANTS = {c.name: c for c in (MICRO, TINY, SMALL, BASE)}
