"""16-bit fixed-point primitives mirroring the paper's FPGA datapath.

Every function here is defined over int32 lanes with *pure integer* ops
(shift / add / mul / compare), exactly mirroring `rust/src/fixed` and
`rust/src/approx`.  The Rust cycle simulator and these jnp implementations
must agree bit-for-bit — that cross-check is enforced by
`rust/tests/cross_check.rs` against the AOT-compiled kernels built from
this module.

Formats (Q<int>.<frac>, signed, two's complement):
  activations / weights      Q7.8   (int16 storage, DATA_FRAC = 8)
  MMU accumulator            Q15.16 (int32, product of two Q7.8)
  EU exponent input v        Q21.10 (int32, EXP_FRAC = 10)
  EU output 2^frac           Q17.14 (int32, OUT_FRAC = 14, value in [1,2))
  softmax probabilities      Q0.15  (int16 storage, PROB_FRAC = 15)

The paper's §III.B / §IV.C-D constants, kept verbatim:
  log2(e)            ~= 1.0111b          = 1 + 2^-1 - 2^-4        (Eq. 6 region)
  -2*log2(e)*sqrt(2/pi) ~= -10.0101b     = -(2 + 2^-2 + 2^-4)     (Eq. 9)
  0.044715           ~= 0.000011b        = 2^-5 + 2^-6            (Eq. 9)
2^frac over frac in [0,1) is an 8-segment piecewise-linear LUT indexed by
the top three fractional bits ("the 9th, 8th, and 7th bits of frac(x_i)",
§IV.C.3); division is the LOD log-domain approximation of Eqs. 11-12.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Format constants (mirrored in rust/src/fixed/mod.rs)
# ---------------------------------------------------------------------------

DATA_FRAC = 8      # Q7.8 activations
WEIGHT_FRAC = 12   # Q3.12 weights (fused weights are O(1); finer grid)
ACC_FRAC = 16      # MMU int32 accumulator (Q7.8 x Q7.8)
EXP_FRAC = 10      # EU exponent domain
OUT_FRAC = 14      # EU 2^frac output, [1,2) -> [16384, 32768)
PROB_FRAC = 15     # softmax output Q0.15

I16_MAX = (1 << 15) - 1
I16_MIN = -(1 << 15)

# EU shift clamp: keep 2^v representable in int32 with headroom for the
# adder tree (n <= 64 terms).  Mirrors `approx::exp2::SHIFT_CLAMP`.
EXP2_SHIFT_MIN = -30
EXP2_SHIFT_MAX = 13

# GELU polynomial input clamp (Q7.8): |x| <= 8.0.  gelu(x) ~= x for x > 4
# and ~= 0 for x < -4, so the clamp is outside the interesting region; it
# bounds x^3 to fit the int32 datapath (hardware saturates identically).
GELU_X_CLAMP = 8 << DATA_FRAC


def _pwl_exp2_tables() -> tuple[np.ndarray, np.ndarray]:
    """8-segment endpoint-interpolated PWL tables for 2^f, f in [0,1).

    K (slope) and B (intercept) in Q2.14; segment s covers [s/8, (s+1)/8).
    Endpoint interpolation keeps the curve continuous and exactly hits
    2^0 = 1 and (limit) 2^1 = 2, mirroring `approx::exp2::{K_LUT, B_LUT}`.
    """
    ks, bs = [], []
    for s in range(8):
        f0, f1 = s / 8.0, (s + 1) / 8.0
        y0, y1 = 2.0 ** f0, 2.0 ** f1
        k = (y1 - y0) / (f1 - f0)
        b = y0 - k * f0
        ks.append(int(round(k * (1 << OUT_FRAC))))
        bs.append(int(round(b * (1 << OUT_FRAC))))
    return np.array(ks, dtype=np.int32), np.array(bs, dtype=np.int32)


EXP2_K, EXP2_B = _pwl_exp2_tables()


# ---------------------------------------------------------------------------
# Quantisation helpers
# ---------------------------------------------------------------------------

def quantize(x, frac: int = DATA_FRAC):
    """float -> int32 fixed point (round-to-nearest-even via jnp.round),
    saturated to int16 range."""
    q = jnp.round(x * (1 << frac)).astype(jnp.int32)
    return jnp.clip(q, I16_MIN, I16_MAX)


def dequantize(q, frac: int = DATA_FRAC):
    return q.astype(jnp.float32) / (1 << frac)


def sat16(x):
    """Saturate int32 lanes into int16 range (still int32 dtype)."""
    return jnp.clip(x, I16_MIN, I16_MAX)


def requantize_acc(acc, rshift: int = ACC_FRAC - DATA_FRAC):
    """MMU write-back: Q15.16 accumulator -> Q7.8, round-half-up, saturate.

    Mirrors `fixed::requantize_acc`."""
    rounded = (acc + (1 << (rshift - 1))) >> rshift
    return sat16(rounded)


# ---------------------------------------------------------------------------
# Shift-add constant multipliers (paper Eqs. 6 / 9)
# ---------------------------------------------------------------------------

def mul_log2e(x):
    """x * 1.0111b = x * 1.4375 via two shifts + two add/subs (paper SIII.B).

    x: int32 fixed point, any frac; result same frac."""
    return x + (x >> 1) - (x >> 4)


def mul_neg2log2e_sqrt2pi(u):
    """u * -10.0101b = -(2u + u/4 + u/16) = -2.3125*u (paper Eq. 9)."""
    return -((u << 1) + (u >> 2) + (u >> 4))


def mul_gelu_cubic(x3):
    """x3 * 0.000011b = x3 * 0.046875 (paper's binary approx of 0.044715)."""
    return (x3 >> 5) + (x3 >> 6)


def mul_gelu_cubic_corrected(x3):
    """12-bit corrected constant: 0.044715 ~= 0.0000101101110b.

    round(0.044715 * 2^12) = 183 = 128 + 32 + 16 + 4 + 2 + 1
    -> x3*183 >> 12 as shift-adds. Ablation mode (DESIGN.md §6)."""
    return ((x3 << 7) + (x3 << 5) + (x3 << 4) + (x3 << 2) + (x3 << 1) + x3) >> 12


# ---------------------------------------------------------------------------
# EU: 2^v via PWL segments + shifter (paper Eq. 10, Fig. 8)
# ---------------------------------------------------------------------------

def exp2_fixed(v, out_frac: int = OUT_FRAC):
    """2^v for v in Q*.EXP_FRAC (int32), returning Q*.out_frac (int32).

    v is split into int(v) (arithmetic floor) and frac(v) in [0,1); the PWL
    LUT evaluates 2^frac in Q2.14 and the barrel shifter applies 2^int.
    Shifts are clamped to [EXP2_SHIFT_MIN, EXP2_SHIFT_MAX] - underflow
    flushes toward 0, overflow saturates (hardware behaviour).
    Mirrors `approx::exp2::exp2_fixed`."""
    v = v.astype(jnp.int32)
    int_part = v >> EXP_FRAC                       # floor
    frac = v - (int_part << EXP_FRAC)              # in [0, 2^10)
    seg = (frac >> (EXP_FRAC - 3)).astype(jnp.int32)  # top 3 frac bits
    # 8-way mux over scalar Q2.14 constants (a LUT in hardware; scalars stay
    # inline-foldable so Pallas kernels capture no constant arrays).
    k = jnp.full_like(v, int(EXP2_K[0]))
    b = jnp.full_like(v, int(EXP2_B[0]))
    for s_idx in range(1, 8):
        k = jnp.where(seg == s_idx, int(EXP2_K[s_idx]), k)
        b = jnp.where(seg == s_idx, int(EXP2_B[s_idx]), b)
    # K(Q2.14) * frac(Q0.10) >> 10 -> Q2.14; + B(Q2.14)
    p = ((k * frac) >> EXP_FRAC) + b               # 2^frac in Q2.14, [1,2)
    shift = int_part + (out_frac - OUT_FRAC)
    shift = jnp.clip(shift, EXP2_SHIFT_MIN, EXP2_SHIFT_MAX)
    left = p << jnp.maximum(shift, 0)
    right = p >> jnp.maximum(-shift, 0)
    return jnp.where(shift >= 0, left, right)


# ---------------------------------------------------------------------------
# LOD + log-domain division (paper Eqs. 11-12, Fig. 9)
# ---------------------------------------------------------------------------

def lod(f):
    """Leading-one detector: bit index of MSB of f (int32 > 0); 0 if f <= 0.

    Branch-free binary search, mirrors `approx::division::lod`."""
    f = f.astype(jnp.int32)
    n = jnp.zeros_like(f)
    for sh in (16, 8, 4, 2, 1):
        big = f >= (1 << sh)
        n = jnp.where(big, n + sh, n)
        f = jnp.where(big, f >> sh, f)
    return n


def log2_approx(f, frac: int):
    """log2(f) ~= w + (m - 1) with f = m * 2^w, m in [1,2)  (Eq. 12).

    f: int32 > 0 with `frac` fractional bits. Returns Q*.EXP_FRAC.
    Mirrors `approx::division::log2_approx`."""
    f = f.astype(jnp.int32)
    pos = lod(f)                                   # MSB index of raw int
    w = pos - frac                                 # integer exponent
    # normalise mantissa to Q(OUT_FRAC): MSB at bit OUT_FRAC
    sh = pos - OUT_FRAC
    m = jnp.where(sh >= 0, f >> jnp.maximum(sh, 0), f << jnp.maximum(-sh, 0))
    frac_part = (m - (1 << OUT_FRAC)) >> (OUT_FRAC - EXP_FRAC)   # Q10
    return (w << EXP_FRAC) + frac_part


def div_exponent(num, num_frac: int, den, den_frac: int):
    """DU: exponent of num/den in Q*.EXP_FRAC (Eq. 12). num, den > 0."""
    return log2_approx(num, num_frac) - log2_approx(den, den_frac)


# ---------------------------------------------------------------------------
# SCU: full hardware softmax dataflow (paper Fig. 6, Eq. 6)
# ---------------------------------------------------------------------------

def softmax_fixed(x_q, axis: int = -1):
    """Hardware softmax over Q7.8 int32 inputs -> Q0.15 int32 outputs.

    Stage 1  FMU      row max
    Stage 2  EU       d = x - max; v = d*log2e (shift-add); p = 2^v (Q2.14)
    Stage 3  AdderTree S = sum p;  DU: e = log2a(p) - log2a(S)
    Stage 4  EU       out = 2^e in Q0.15
    Mirrors `approx::softmax::softmax_fixed`."""
    x_q = x_q.astype(jnp.int32)
    xmax = jnp.max(x_q, axis=axis, keepdims=True)
    d = x_q - xmax                                  # Q7.8, <= 0
    v = mul_log2e(d) << (EXP_FRAC - DATA_FRAC)      # Q*.10
    p = exp2_fixed(v, OUT_FRAC)                     # Q2.14 in (0, 2^14]
    p = jnp.maximum(p, 1)                           # hardware floor: 1 ulp
    s = jnp.sum(p, axis=axis, keepdims=True)        # int32, n<=64 safe
    e = div_exponent(p, OUT_FRAC, s, OUT_FRAC)      # Q*.10
    out = exp2_fixed(e, PROB_FRAC)                  # Q0.15
    return jnp.clip(out, 0, I16_MAX)


# ---------------------------------------------------------------------------
# GCU: full hardware GELU dataflow (paper Fig. 10, Eqs. 8-9)
# ---------------------------------------------------------------------------

def gelu_fixed(x_q, corrected_cubic: bool = False):
    """Hardware GELU over Q7.8 int32 inputs -> Q7.8 int32 outputs.

    Stage 1  poly  s = -2log2e*sqrt(2/pi) * (x + 0.044715 x^3)  (shift-add)
    Stage 2  EU    p = 2^s                                       (Q2.14)
    Stage 3  DU    e = log2a(|x|) - log2a(1 + p)
    Stage 4  EU    |g| = 2^e;  g = sign(x) * |g|
    Mirrors `approx::gelu::gelu_fixed`."""
    x_q = x_q.astype(jnp.int32)
    xc = jnp.clip(x_q, -GELU_X_CLAMP, GELU_X_CLAMP)
    x2 = (xc * xc) >> DATA_FRAC                     # Q7.8 (positive)
    x3 = (x2 * xc) >> DATA_FRAC                     # Q*.8, |x3| <= 512*256
    cub = mul_gelu_cubic_corrected(x3) if corrected_cubic else mul_gelu_cubic(x3)
    u = xc + cub                                    # Q*.8
    s = mul_neg2log2e_sqrt2pi(u)                    # Q*.8
    s10 = s << (EXP_FRAC - DATA_FRAC)               # Q*.10
    p = exp2_fixed(s10, OUT_FRAC)                   # 2^s in Q2.14 (clamped)
    den = p + (1 << OUT_FRAC)                       # 1 + 2^s, Q2.14
    ax = jnp.abs(x_q)
    e = div_exponent(jnp.maximum(ax, 1), DATA_FRAC, den, OUT_FRAC)
    mag = exp2_fixed(e, DATA_FRAC)                  # Q7.8
    g = jnp.sign(x_q) * mag
    return sat16(jnp.where(ax == 0, 0, g))


# ---------------------------------------------------------------------------
# PEANO-style division/root-free normalisation (arXiv 2406.14854 family):
# the alternative SCU/GCU design of `accel::nonlinear` in the Rust crate.
# Same front ends as softmax_fixed / gelu_fixed; the log-domain DU is
# replaced by a shift-add reciprocal (four-term Horner geometric series,
# relative truncation error <= 2^-5 = 3.125%). Mirrors `approx::peano`.
# ---------------------------------------------------------------------------

def recip_shift_add(den):
    """(h, k1) with h ~= 2^(2*k1) / den, k1 = bit length of den.

    den: int32 > 0. Element-wise over arrays. Mirrors
    `approx::peano::recip_shift_add` (which runs in i64; the jnp path
    promotes to int64 for the t*h products)."""
    den = jnp.asarray(den).astype(jnp.int64)
    k1 = lod(den.astype(jnp.int32)) + 1              # bit length
    one = jnp.int64(1) << k1.astype(jnp.int64)
    t = one - den
    h = one + t
    for _ in range(3):
        h = one + ((t * h) >> k1)
    return h, k1


def softmax_fixed_peano(x_q, axis: int = -1):
    """PEANO softmax: Q7.8 int32 -> Q0.15 int32.

    Stages 1-2 identical to softmax_fixed (max, shift-add x log2e, PWL
    2^v, 1-ulp floor); normalisation p/S via recip_shift_add. The row
    sum always has its max lane at exactly 1.0 (Q2.14), so k1 >= 15 and
    the shift 2*k1 - PROB_FRAC is non-negative. Mirrors
    `approx::peano::softmax_row_peano`."""
    x_q = x_q.astype(jnp.int32)
    xmax = jnp.max(x_q, axis=axis, keepdims=True)
    d = x_q - xmax
    v = mul_log2e(d) << (EXP_FRAC - DATA_FRAC)
    p = jnp.maximum(exp2_fixed(v, OUT_FRAC), 1)      # Q2.14
    s = jnp.sum(p, axis=axis, keepdims=True)
    h, k1 = recip_shift_add(s)
    sh = 2 * k1.astype(jnp.int64) - PROB_FRAC
    out = (p.astype(jnp.int64) * h) >> sh
    return jnp.clip(out, 0, I16_MAX).astype(jnp.int32)


def gelu_fixed_peano(x_q):
    """PEANO GELU: Q7.8 int32 -> Q7.8 int32.

    Polynomial + PWL-2^s front end of gelu_fixed; |x|/(1 + 2^s) via
    recip_shift_add (den in [2^14, 2^15] so k1 = 15). Mirrors
    `approx::peano::gelu_fixed_peano`."""
    x_q = x_q.astype(jnp.int32)
    xc = jnp.clip(x_q, -GELU_X_CLAMP, GELU_X_CLAMP)
    x2 = (xc * xc) >> DATA_FRAC
    x3 = (x2 * xc) >> DATA_FRAC
    u = xc + mul_gelu_cubic(x3)
    s = mul_neg2log2e_sqrt2pi(u)
    s10 = s << (EXP_FRAC - DATA_FRAC)
    p = exp2_fixed(s10, OUT_FRAC)
    den = p + (1 << OUT_FRAC)
    ax = jnp.abs(x_q)
    h, k1 = recip_shift_add(den)
    sh = 2 * k1.astype(jnp.int64) - OUT_FRAC
    mag = ((ax.astype(jnp.int64) * h) >> sh).astype(jnp.int32)
    g = jnp.sign(x_q) * mag
    return sat16(jnp.where(ax == 0, 0, g))
