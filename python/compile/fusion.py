"""BN -> linear fusion (paper §III.A, Eqs. 2-4) and weight quantisation.

Fusion directions used by the BN-Swin block (Fig. 2):

  pre-fuse   y = (BN(x)) @ W + b      ->  W' = diag(s) W,  b' = b + (β - μs) W
  post-fuse  y = BN(x @ W + b)        ->  W' = W diag(s),  b' = (b - μ)s + β

with s = γ / sqrt(σ² + ε).  The attention Q scale 1/sqrt(d_h) is folded
into W_q at the same time (paper §IV.A: "Multiply the weight parameters
corresponding to Q by a scaling factor").

`fuse_params` is validated by `python/tests/test_fusion.py`: fused forward
must match unfused forward to float tolerance on random inputs — this is
the inference-efficiency claim of contribution C1.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import fixedpoint as fp
from .configs import SwinConfig

EPS = 1e-5


def _scale(bn):
    return bn["gamma"] / jnp.sqrt(bn["var"] + EPS)


def _pre_fuse(bn, w, b):
    """Fold BN applied *before* the linear into (w, b)."""
    s = _scale(bn)
    w2 = w * s[:, None]
    b2 = b + (bn["beta"] - bn["mean"] * s) @ w
    return w2, b2


def _post_fuse(bn, w, b):
    """Fold BN applied *after* the linear into (w, b)."""
    s = _scale(bn)
    return w * s[None, :], (b - bn["mean"]) * s + bn["beta"]


def fuse_params(cfg: SwinConfig, params: dict) -> dict:
    """Unfused float tree -> fused float tree (no BN dicts anywhere)."""
    out = {"stages": []}

    pe = params["patch_embed"]
    w, b = _post_fuse(pe["bn"], pe["w"], pe["b"])
    out["patch_embed"] = {"w": w, "b": b}

    for s_idx, stage in enumerate(params["stages"]):
        c = cfg.stage_dim(s_idx)
        nh = cfg.num_heads[s_idx]
        dh = c // nh
        blocks = []
        for blk in stage["blocks"]:
            a = blk["attn"]
            wqkv, bqkv = _pre_fuse(blk["bn1"], a["wqkv"], a["bqkv"])
            # fold the attention scale into the Q third
            scale = dh ** -0.5
            wqkv = wqkv.at[:, :c].multiply(scale)
            bqkv = bqkv.at[:c].multiply(scale)
            w1, b1 = _pre_fuse(blk["bn2"], blk["mlp"]["w1"], blk["mlp"]["b1"])
            w1, b1 = _post_fuse(blk["mlp"]["bn3"], w1, b1)
            w2, b2 = _post_fuse(blk["mlp"]["bn4"],
                                blk["mlp"]["w2"], blk["mlp"]["b2"])
            blocks.append({
                "attn": {"wqkv": wqkv, "bqkv": bqkv,
                         "wproj": a["wproj"], "bproj": a["bproj"],
                         "rel_bias": a["rel_bias"]},
                "mlp": {"w1": w1, "b1": b1, "w2": w2, "b2": b2},
            })
        merge = None
        if stage["merge"] is not None:
            mg = stage["merge"]
            w, b = _pre_fuse(mg["bn"], mg["w"], mg["b"])
            merge = {"w": w, "b": b}
        out["stages"].append({"blocks": blocks, "merge": merge})

    hd = params["head"]
    w, b = _pre_fuse(hd["bn"], hd["w"], hd["b"])
    out["head"] = {"w": w, "b": b}
    return out


# ---------------------------------------------------------------------------
# Quantisation of the fused tree for the fixed-point datapath
# ---------------------------------------------------------------------------

def _qw(w):
    """Weights: Q3.12."""
    return fp.quantize(w, fp.WEIGHT_FRAC)


def _qb(b):
    """Biases: Q7.8 (added post-requantisation)."""
    return fp.quantize(b, fp.DATA_FRAC)


def quantize_fused(cfg: SwinConfig, fused: dict) -> dict:
    """Fused float tree -> int32 tree for `model.forward_fixed` and for the
    Rust simulator (exported via `export.write_weights`)."""
    q = {"stages": []}
    q["patch_embed"] = {"wq": _qw(fused["patch_embed"]["w"]),
                        "bq": _qb(fused["patch_embed"]["b"])}
    for stage in fused["stages"]:
        blocks = []
        for blk in stage["blocks"]:
            blocks.append({
                "attn": {
                    "wqkv": _qw(blk["attn"]["wqkv"]),
                    "bqkv": _qb(blk["attn"]["bqkv"]),
                    "wproj": _qw(blk["attn"]["wproj"]),
                    "bproj": _qb(blk["attn"]["bproj"]),
                    "rel_bias_q": _qb(blk["attn"]["rel_bias"]),
                },
                "mlp": {"w1q": _qw(blk["mlp"]["w1"]),
                        "b1q": _qb(blk["mlp"]["b1"]),
                        "w2q": _qw(blk["mlp"]["w2"]),
                        "b2q": _qb(blk["mlp"]["b2"])},
            })
        merge = None
        if stage["merge"] is not None:
            merge = {"wq": _qw(stage["merge"]["w"]),
                     "bq": _qb(stage["merge"]["b"])}
        q["stages"].append({"blocks": blocks, "merge": merge})
    q["head"] = {"wq": _qw(fused["head"]["w"]),
                 "bq": _qb(fused["head"]["b"])}
    return q


# ---------------------------------------------------------------------------
# Binary export for the Rust simulator
# ---------------------------------------------------------------------------

def flatten_qtree(q: dict, prefix: str = "") -> list[tuple[str, np.ndarray]]:
    """Deterministic (name, int array) list, names like
    `stages.0.blocks.1.attn.wqkv`."""
    items: list[tuple[str, np.ndarray]] = []

    def visit(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                visit(node[k], f"{path}.{k}" if path else k)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                visit(v, f"{path}.{i}")
        elif node is None:
            return
        else:
            items.append((path, np.asarray(node)))

    visit(q, prefix)
    return items


def write_weights(q: dict, bin_path: str, manifest_path: str) -> None:
    """Write int16 little-endian blob + JSON manifest (name/shape/offset).

    The Rust side (`model::weights`) mmaps the blob with the manifest; all
    values fit int16 by construction (quantize saturates)."""
    import json

    items = flatten_qtree(q)
    offset = 0
    manifest = []
    with open(bin_path, "wb") as f:
        for name, arr in items:
            a16 = arr.astype(np.int16)
            assert np.all(arr == a16), f"{name} exceeds int16"
            f.write(a16.tobytes(order="C"))
            manifest.append({"name": name, "shape": list(arr.shape),
                             "offset": offset, "len": int(a16.size)})
            offset += a16.size * 2
    with open(manifest_path, "w") as f:
        json.dump({"tensors": manifest, "weight_frac": fp.WEIGHT_FRAC,
                   "data_frac": fp.DATA_FRAC}, f, indent=1)
