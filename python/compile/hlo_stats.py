"""L2 perf tooling: inspect the lowered HLO of exported artifacts.

Used by the §Perf pass (EXPERIMENTS.md) and `python/tests/test_aot.py` to
assert structural properties the export *must* have:

  * zero normalisation ops on the request path (BN fully fused — the
    paper's contribution C1);
  * no f64 anywhere (the datapath is f32/i32 only);
  * op histograms per artifact (dot/add/exp coverage) so regressions in
    fusion or lowering show up as test failures, not silent slowdowns.

Run as a module for a report: `python -m compile.hlo_stats ../artifacts`.
"""

from __future__ import annotations

import os
import re
import sys
from collections import Counter


OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[a-z0-9]+\[[^\]]*\][^ ]*\s+([a-z0-9\-]+)\(")
DTYPE_RE = re.compile(r"=\s*([a-z0-9]+)\[")


def op_histogram(hlo_text: str) -> Counter:
    """Count HLO opcodes (one instruction per line in text format)."""
    ops: Counter = Counter()
    for line in hlo_text.splitlines():
        m = OP_RE.match(line)
        if m:
            ops[m.group(1)] += 1
    return ops


def dtype_histogram(hlo_text: str) -> Counter:
    dts: Counter = Counter()
    for line in hlo_text.splitlines():
        m = DTYPE_RE.search(line)
        if m:
            dts[m.group(1)] += 1
    return dts


def flop_estimate(hlo_text: str) -> int:
    """Rough FLOP count from dot shapes: 2 * prod(out dims) * contracted.

    Good enough to sanity-check against the analytic MAC counts."""
    total = 0
    for line in hlo_text.splitlines():
        if " dot(" not in line:
            continue
        shape = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*\w+\[([0-9,]*)\]", line)
        contract = re.search(r"rhs_contracting_dims=\{(\d+)", line)
        rhs = re.search(r"dot\([^,]+, %?([\w.\-]+)", line)
        if not shape:
            continue
        out = 1
        for d in shape.group(1).split(","):
            if d:
                out *= int(d)
        # find contracted extent from the rhs operand's shape in the text
        k = 1
        if rhs and contract:
            opname = rhs.group(1)
            decl = re.search(
                rf"%?{re.escape(opname)}\s*=\s*\w+\[([0-9,]*)\]", hlo_text
            )
            if decl:
                dims = [int(d) for d in decl.group(1).split(",") if d]
                ci = int(contract.group(1))
                if ci < len(dims):
                    k = dims[ci]
        total += 2 * out * k
    return total


FORBIDDEN_ON_REQUEST_PATH = (
    "batch-norm-inference",
    "batch-norm-training",
    "rng",  # no RNG baked into serving artifacts
)


def check_artifact(path: str) -> dict:
    with open(path) as f:
        text = f.read()
    ops = op_histogram(text)
    dts = dtype_histogram(text)
    problems = [op for op in FORBIDDEN_ON_REQUEST_PATH if ops.get(op)]
    if dts.get("f64"):
        problems.append("f64-present")
    return {
        "ops": ops,
        "dtypes": dts,
        "flops": flop_estimate(text),
        "problems": problems,
    }


def main() -> None:
    art_dir = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    for name in sorted(os.listdir(art_dir)):
        if not name.endswith(".hlo.txt"):
            continue
        info = check_artifact(os.path.join(art_dir, name))
        top = ", ".join(f"{op}×{n}" for op, n in info["ops"].most_common(6))
        print(f"{name}")
        print(f"  ops: {sum(info['ops'].values())} ({top})")
        print(f"  est. FLOPs: {info['flops'] / 1e6:.1f} M")
        if info["problems"]:
            print(f"  PROBLEMS: {info['problems']}")


if __name__ == "__main__":
    main()
