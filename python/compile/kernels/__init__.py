"""Layer-1 Pallas kernels: the accelerator's compute units as TPU-style kernels.

- `mmu`:     blocked 16-bit fixed-point matmul (the paper's MMU, Fig. 4)
- `softmax`: hardware softmax dataflow (SCU, Fig. 6 / Eq. 6)
- `gelu`:    hardware GELU dataflow (GCU, Fig. 10 / Eqs. 8-9)
- `ref`:     pure-jnp float oracles used by pytest

All kernels run with `interpret=True` (CPU PJRT cannot execute Mosaic
custom-calls); see DESIGN.md §Hardware-Adaptation for the FPGA->TPU mapping.
"""
