"""GCU Pallas kernel: the paper's hardware GELU (Fig. 10, Eqs. 8-9).

Elementwise over Q7.8 int32 tensors; one program per tile of rows.  The
four GCU stages (polynomial s(x) via shift-add constants, EU 2^s, DU
log-domain division exponent, EU final 2^e) are all int32 shift/add ops,
bit-identical to `rust/src/approx/gelu.rs`.

The `corrected` flag swaps the paper's 6-bit cubic constant
(0.000011b = 0.046875, +4.8% off 0.044715) for a 12-bit shift-add chain —
the ablation of DESIGN.md §6.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fixedpoint import gelu_fixed

ROW_BLOCK = 64


def _gcu_kernel(x_ref, o_ref, *, corrected: bool):
    o_ref[...] = gelu_fixed(x_ref[...], corrected_cubic=corrected)


def gelu_rows(x_q, *, corrected: bool = False, row_block: int = ROW_BLOCK):
    """Hardware GELU over a (rows, n) int32 Q7.8 array -> Q7.8."""
    rows, n = x_q.shape
    if rows % row_block != 0:
        row_block = rows
    kernel = functools.partial(_gcu_kernel, corrected=corrected)
    return pl.pallas_call(
        kernel,
        grid=(rows // row_block,),
        in_specs=[pl.BlockSpec((row_block, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.int32),
        interpret=True,
    )(x_q)
