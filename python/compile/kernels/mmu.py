"""MMU Pallas kernel: the paper's blocked matrix-multiply engine (Fig. 4).

The FPGA MMU is 32 PEs x 49 multipliers: each grid step consumes an A-tile
(M^2=49, c_i=32) and a B-tile (c_i=32, c_o=32), producing 49x32 int32
partial sums that an "Accumulation module" folds over C_I/c_i passes before
requantising Q15.16 -> Q7.8 on write-back.

TPU mapping (DESIGN.md §Hardware-Adaptation): output-stationary BlockSpec —
grid = (rows/TM, C_O/TN, C_I/TK); the accumulator tile lives in the output
ref in VMEM across the k axis (revisited because k is the innermost grid
dim), and requantisation happens on the final k step.  int16 operands are
widened to int32 before the dot, matching DSP48E1 16x16->32 semantics.

VMEM per step: A (49x32x4B) + B (32x32x4B) + acc (49x32x4B) ~= 16.6 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fixedpoint import DATA_FRAC, ACC_FRAC, requantize_acc

# Paper tile sizes: M^2 = 49 rows (window 7x7), c_i = c_o = 32.
TILE_M = 49
TILE_K = 32
TILE_N = 32


def _mmu_kernel(a_ref, b_ref, o_ref, *, nk: int, rshift: int):
    """One MMU pass: o += A_tile @ B_tile; requantise on the last pass.

    The output block is output-stationary: the k grid axis is innermost, so
    the same (i, j) tile stays resident in VMEM across all C_I/c_i passes —
    this IS the paper's "Accumulation module" (a BRAM bank the adder tree
    folds into over C_I/c_i cycles)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    # 49x32 @ 32x32 int32 dot — the 32x49 PE array's MAC + adder tree.
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _writeback():
        o_ref[...] = requantize_acc(o_ref[...], rshift).astype(jnp.int32)


def matmul_fixed(a_q, b_q, *, rshift: int = ACC_FRAC - DATA_FRAC,
                 tile_m: int = TILE_M, tile_k: int = TILE_K,
                 tile_n: int = TILE_N):
    """Blocked fixed-point matmul: (R, K) int @ (K, N) int -> (R, N) Q7.8.

    R must be a multiple of tile_m, K of tile_k, N of tile_n — the DSU is
    responsible for zero-padding (paper §IV.B K^T exception); see
    `pad_operands`.
    """
    r, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, (a_q.shape, b_q.shape)
    assert r % tile_m == 0 and k % tile_k == 0 and n % tile_n == 0, (
        f"MMU operands must be tile-aligned, got {a_q.shape} @ {b_q.shape}")
    nk = k // tile_k
    grid = (r // tile_m, n // tile_n, nk)
    kernel = functools.partial(_mmu_kernel, nk=nk, rshift=rshift)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.int32),
        interpret=True,
    )(a_q, b_q)


def pad_operands(a_q, b_q, *, tile_m: int = TILE_M, tile_k: int = TILE_K,
                 tile_n: int = TILE_N):
    """Zero-pad operands to MMU tile alignment (the paper's K^T expansion).

    Returns (a_pad, b_pad, original_n) — callers slice the output back to
    original_n columns.  Padding with zeros leaves valid outputs untouched
    (the 'invalid computations' of paper §V.A).
    """
    r, k = a_q.shape
    _, n = b_q.shape
    rp = (-r) % tile_m
    kp = (-k) % tile_k
    np_ = (-n) % tile_n
    a_pad = jnp.pad(a_q, ((0, rp), (0, kp)))
    b_pad = jnp.pad(b_q, ((0, kp), (0, np_)))
    return a_pad, b_pad, n
