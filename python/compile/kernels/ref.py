"""Pure-jnp float oracles for the Pallas kernels (pytest references).

Three tiers per op:
  *_exact   — textbook float32 math (the "what the network wants" truth)
  *_approx  — the paper's approximate dataflow evaluated in float32
              (isolates fixed-point error from approximation error)
  the Pallas kernels themselves are the fixed-point implementations.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

SQRT_2_OVER_PI = math.sqrt(2.0 / math.pi)

# The paper's shift-add constants, §III.B (exact binary values).
LOG2E_PAPER = 1.0 + 0.5 - 0.0625                 # 1.0111b  = 1.4375
NEG2LOG2E_S2PI_PAPER = -(2.0 + 0.25 + 0.0625)    # -10.0101b = -2.3125
CUBIC_PAPER = 0.046875                           # 0.000011b


def matmul_exact(a, b):
    return jnp.matmul(a, b)


def softmax_exact(x, axis: int = -1):
    x = x - jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def gelu_exact(x):
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + 0.044715 * x ** 3)))


def _log2_lod_float(f):
    """Eq. 12 in float: log2(f) ~= w + (m - 1), f = m * 2^w, m in [1,2)."""
    w = jnp.floor(jnp.log2(f))
    m = f / jnp.exp2(w)
    return w + (m - 1.0)


def softmax_approx(x, axis: int = -1):
    """Paper Eq. 6 dataflow in float32 (shift-add log2e + LOD division)."""
    d = x - jnp.max(x, axis=axis, keepdims=True)
    p = jnp.exp2(LOG2E_PAPER * d)
    s = jnp.sum(p, axis=axis, keepdims=True)
    e = _log2_lod_float(p) - _log2_lod_float(s)
    return jnp.exp2(e)


def gelu_approx(x):
    """Paper Eq. 8/9 dataflow in float32.

    x and the exponent s are clamped exactly like the fixed-point datapath
    (|x| <= 8, shift clamp) so the float model stays finite where the
    hardware saturates."""
    xc = jnp.clip(x, -8.0, 8.0)
    s = NEG2LOG2E_S2PI_PAPER * (xc + CUBIC_PAPER * xc ** 3)
    p = jnp.exp2(jnp.clip(s, -30.0, 13.0))
    ax = jnp.maximum(jnp.abs(x), 1e-20)
    e = _log2_lod_float(ax) - _log2_lod_float(1.0 + p)
    return jnp.sign(x) * jnp.exp2(e)
