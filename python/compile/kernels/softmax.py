"""SCU Pallas kernel: the paper's hardware softmax (Fig. 6, Eq. 6).

Grid: one program per block of attention rows; each program runs the full
four-stage SCU dataflow over its rows' last axis:

  Stage 1  FMU        row max (the grouped compare tree of Fig. 7 — on TPU a
                      `jnp.max` tree reduction with identical associativity)
  Stage 2  EU         d = x - max; v = d * log2e via shift-add; p = 2^v (PWL)
  Stage 3  AdderTree  S = sum(p);  DU: e = log2a(p) - log2a(S)   (Eq. 12)
  Stage 4  EU         out = 2^e in Q0.15

All arithmetic is int32 shift/add/compare — bit-identical to
`rust/src/approx/softmax.rs`.  Rows shorter than the padded lane width are
masked with NEG_PAD so padding never wins the max and contributes ~0 to S
(mirrors the DSU zero-pad convention of paper §IV.B).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..fixedpoint import softmax_fixed

# Sentinel for padded lanes: very negative Q7.8 -> p == 1 ulp after the EU
# floor, a negligible contribution to S (exactly what padded-with-minimum
# hardware lanes produce).
NEG_PAD = -(1 << 14)

ROW_BLOCK = 49  # rows per program: one attention window's score matrix


def _scu_kernel(x_ref, o_ref, *, n_valid: int):
    x = x_ref[...]
    if n_valid != x.shape[-1]:
        lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, len(x.shape) - 1)
        x = jnp.where(lane < n_valid, x, NEG_PAD)
    o_ref[...] = softmax_fixed(x, axis=-1)


def softmax_rows(x_q, *, n_valid: int | None = None,
                 row_block: int = ROW_BLOCK):
    """Hardware softmax over the last axis of a (rows, n) int32 array.

    `n_valid`: number of real lanes (rest are padding to be ignored);
    defaults to all lanes.
    """
    rows, n = x_q.shape
    n_valid = n if n_valid is None else n_valid
    if rows % row_block != 0:
        row_block = rows  # single program; caller keeps rows window-aligned
    kernel = functools.partial(_scu_kernel, n_valid=n_valid)
    return pl.pallas_call(
        kernel,
        grid=(rows // row_block,),
        in_specs=[pl.BlockSpec((row_block, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.int32),
        interpret=True,
    )(x_q)
