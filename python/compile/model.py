"""Layer-2: BN-Swin Transformer forward graph in JAX.

This is the paper's modified Swin (Fig. 2): every LayerNorm replaced by
BatchNorm, plus two extra BNs after the FFN's two linear layers ([17]'s
stabilisation, which the paper adopts).  At inference all BNs fold into
adjacent linears (`fusion.py`, paper Eqs. 2-4), so the exported HLO
contains *zero* normalisation ops on the request path.

Two datapaths over the same parameter tree:

  forward_float  — float32; exact softmax/GELU (or the paper's approximate
                   dataflow in float with approx_nonlinear=True).  Used for
                   the serving artifacts and as accuracy reference.
  forward_fixed  — full 16-bit fixed-point path through the Layer-1 Pallas
                   kernels (MMU / SCU / GCU); bit-identical to the Rust
                   cycle simulator's functional model.  Requires fused +
                   quantised params (`fusion.quantize_fused`).

Layout: images are (B, H, W, 3); tokens are kept as (B, H, W, C) feature
maps between blocks, windowed to (B*nW, M*M, C) inside attention.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import fixedpoint as fp
from .configs import SwinConfig
from .kernels import gelu as gelu_k
from .kernels import mmu
from .kernels import ref
from .kernels import softmax as softmax_k

MASK_FILL = -100.0  # attention mask additive fill (standard Swin value)


# ---------------------------------------------------------------------------
# Parameter initialisation (deterministic; trunc-normal like timm's Swin)
# ---------------------------------------------------------------------------

def _bn_init(dim: int) -> dict:
    return {
        "gamma": jnp.ones((dim,), jnp.float32),
        "beta": jnp.zeros((dim,), jnp.float32),
        "mean": jnp.zeros((dim,), jnp.float32),
        "var": jnp.ones((dim,), jnp.float32),
    }


def _linear_init(key, din: int, dout: int, std: float = 0.02) -> dict:
    w = std * jax.random.truncated_normal(key, -2.0, 2.0, (din, dout))
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((dout,), jnp.float32)}


def init_params(cfg: SwinConfig, key) -> dict:
    """Full unfused float parameter tree (BN stats at identity defaults).

    For realistic BN statistics (non-trivial fusion), perturb with
    `randomize_bn_stats`."""
    keys = iter(jax.random.split(key, 4096))
    patch_dim = cfg.patch_size * cfg.patch_size * cfg.in_chans
    params = {
        "patch_embed": {**_linear_init(next(keys), patch_dim, cfg.embed_dim),
                        "bn": _bn_init(cfg.embed_dim)},
        "stages": [],
        "head": {**_linear_init(next(keys), cfg.final_dim, cfg.num_classes),
                 "bn": _bn_init(cfg.final_dim)},
    }
    m = cfg.window
    for s in range(cfg.num_stages):
        c = cfg.stage_dim(s)
        nh = cfg.num_heads[s]
        blocks = []
        for _ in range(cfg.depths[s]):
            blocks.append({
                "bn1": _bn_init(c),
                "attn": {
                    "wqkv": _linear_init(next(keys), c, 3 * c)["w"],
                    "bqkv": jnp.zeros((3 * c,), jnp.float32),
                    "wproj": _linear_init(next(keys), c, c)["w"],
                    "bproj": jnp.zeros((c,), jnp.float32),
                    "rel_bias": 0.02 * jax.random.normal(
                        next(keys), ((2 * m - 1) ** 2, nh)).astype(jnp.float32),
                },
                "bn2": _bn_init(c),
                "mlp": {
                    "w1": _linear_init(next(keys), c, cfg.mlp_ratio * c)["w"],
                    "b1": jnp.zeros((cfg.mlp_ratio * c,), jnp.float32),
                    "bn3": _bn_init(cfg.mlp_ratio * c),
                    "w2": _linear_init(next(keys), cfg.mlp_ratio * c, c)["w"],
                    "b2": jnp.zeros((c,), jnp.float32),
                    "bn4": _bn_init(c),
                },
            })
        merge = None
        if s + 1 < cfg.num_stages:
            merge = {"bn": _bn_init(4 * c),
                     **_linear_init(next(keys), 4 * c, 2 * c)}
        params["stages"].append({"blocks": blocks, "merge": merge})
    return params


def randomize_bn_stats(params: dict, key, scale: float = 0.3) -> dict:
    """Give every BN non-trivial (but well-conditioned) stats, as if trained.

    mean ~ N(0, scale); var ~ lognormal around 1; gamma ~ 1 + N(0, scale/2);
    beta ~ N(0, scale/2).  Makes the fusion identity (Eqs. 2-4) a real test
    rather than a no-op."""
    leaves = []

    def visit(node):
        if isinstance(node, dict):
            if set(node) == {"gamma", "beta", "mean", "var"}:
                leaves.append(node)
            else:
                for v in node.values():
                    visit(v)
        elif isinstance(node, list):
            for v in node:
                visit(v)

    visit(params)
    keys = jax.random.split(key, len(leaves) * 4)
    for i, bn in enumerate(leaves):
        k0, k1, k2, k3 = keys[4 * i:4 * i + 4]
        d = bn["mean"].shape[0]
        bn["mean"] = scale * jax.random.normal(k0, (d,))
        bn["var"] = jnp.exp(scale * jax.random.normal(k1, (d,)))
        bn["gamma"] = 1.0 + 0.5 * scale * jax.random.normal(k2, (d,))
        bn["beta"] = 0.5 * scale * jax.random.normal(k3, (d,))
    return params


# ---------------------------------------------------------------------------
# Window helpers (shared by float and fixed paths — pure reshapes)
# ---------------------------------------------------------------------------

def window_partition(x, m: int):
    """(B, H, W, C) -> (B * nW, m*m, C)."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // m, m, w // m, m, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b * (h // m) * (w // m), m * m, c)


def window_reverse(win, m: int, h: int, w: int):
    """(B * nW, m*m, C) -> (B, H, W, C)."""
    nw = (h // m) * (w // m)
    b = win.shape[0] // nw
    c = win.shape[-1]
    x = win.reshape(b, h // m, w // m, m, m, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, h, w, c)


@functools.lru_cache(maxsize=None)
def relative_position_index(m: int) -> np.ndarray:
    """Standard Swin (2m-1)^2 bias table index, shape (m*m, m*m)."""
    coords = np.stack(np.meshgrid(np.arange(m), np.arange(m), indexing="ij"))
    flat = coords.reshape(2, -1)
    rel = flat[:, :, None] - flat[:, None, :]          # (2, m^2, m^2)
    rel = rel.transpose(1, 2, 0) + (m - 1)
    return (rel[..., 0] * (2 * m - 1) + rel[..., 1]).astype(np.int32)


@functools.lru_cache(maxsize=None)
def shift_attn_mask(h: int, w: int, m: int, shift: int) -> Optional[np.ndarray]:
    """SW-MSA additive mask, (nW, m*m, m*m) of {0, MASK_FILL}; None if
    shift == 0 (W-MSA needs no mask)."""
    if shift == 0:
        return None
    img = np.zeros((h, w), np.float32)
    cnt = 0
    slices = (slice(0, -m), slice(-m, -shift), slice(-shift, None))
    for hs in slices:
        for ws in slices:
            img[hs, ws] = cnt
            cnt += 1
    # pure-numpy window partition (must stay outside any jax trace)
    win = img.reshape(h // m, m, w // m, m).transpose(0, 2, 1, 3)
    win = win.reshape(-1, m * m)                                # (nW, m*m)
    diff = win[:, None, :] - win[:, :, None]
    return np.where(diff != 0, MASK_FILL, 0.0).astype(np.float32)


def patch_embed_tokens(x, patch: int):
    """im2col for the 4x4/stride-4 conv (paper §IV.B): (B,H,W,3) ->
    (B, H/4, W/4, 48) patch vectors, flattened in (ph, pw, chan) order."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // patch, patch, w // patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, h // patch, w // patch, patch * patch * c)


# ---------------------------------------------------------------------------
# Float path
# ---------------------------------------------------------------------------

def _bn_apply(x, bn):
    inv = bn["gamma"] / jnp.sqrt(bn["var"] + 1e-5)
    return (x - bn["mean"]) * inv + bn["beta"]


def _maybe_bn(x, node, name):
    return _bn_apply(x, node[name]) if name in node else x


def _attention_float(xw, attn, nh: int, mask, approx: bool, fused: bool):
    """xw: (B_, N, C) windowed tokens -> (B_, N, C)."""
    b_, n, c = xw.shape
    dh = c // nh
    qkv = xw @ attn["wqkv"] + attn["bqkv"]
    qkv = qkv.reshape(b_, n, 3, nh, dh).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]        # (B_, nh, N, dh)
    if not fused:
        q = q * (dh ** -0.5)                # folded into wq when fused
    scores = jnp.einsum("bhnd,bhmd->bhnm", q, k)
    m = relative_position_index(int(round(n ** 0.5)))
    bias = attn["rel_bias"][m.reshape(-1)].reshape(n, n, nh)
    scores = scores + bias.transpose(2, 0, 1)[None]
    if mask is not None:
        nw = mask.shape[0]
        scores = scores.reshape(b_ // nw, nw, nh, n, n) + mask[None, :, None]
        scores = scores.reshape(b_, nh, n, n)
    probs = (ref.softmax_approx(scores) if approx else
             ref.softmax_exact(scores))
    out = jnp.einsum("bhnm,bhmd->bhnd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b_, n, c)
    return out @ attn["wproj"] + attn["bproj"]


def forward_float(cfg: SwinConfig, params: dict, images,
                  approx_nonlinear: bool = False) -> jnp.ndarray:
    """(B, H, W, 3) float32 -> (B, num_classes) logits.

    Works on both unfused params (BN applied explicitly — inference
    semantics, running stats) and fused params (BN dicts absent)."""
    fused = "bn" not in params["patch_embed"]
    gelu_fn = ref.gelu_approx if approx_nonlinear else ref.gelu_exact
    m = cfg.window

    x = patch_embed_tokens(images, cfg.patch_size)
    x = x @ params["patch_embed"]["w"] + params["patch_embed"]["b"]
    x = _maybe_bn(x, params["patch_embed"], "bn")

    for s, stage in enumerate(params["stages"]):
        res = cfg.stage_resolution(s)
        nh = cfg.num_heads[s]
        for i, blk in enumerate(stage["blocks"]):
            shift = 0 if (i % 2 == 0 or res <= m) else m // 2
            shortcut = x
            h = _maybe_bn(x, blk, "bn1")
            if shift:
                h = jnp.roll(h, (-shift, -shift), axis=(1, 2))
            hw = window_partition(h, m)
            mask = shift_attn_mask(res, res, m, shift)
            mask = None if mask is None else jnp.asarray(mask)
            hw = _attention_float(hw, blk["attn"], nh, mask,
                                  approx_nonlinear, fused)
            h = window_reverse(hw, m, res, res)
            if shift:
                h = jnp.roll(h, (shift, shift), axis=(1, 2))
            x = shortcut + h
            # FFN: lin1 -> BN -> GELU -> lin2 -> BN  (paper Fig. 2)
            shortcut = x
            h = _maybe_bn(x, blk, "bn2")
            h = h @ blk["mlp"]["w1"] + blk["mlp"]["b1"]
            h = _maybe_bn(h, blk["mlp"], "bn3")
            h = gelu_fn(h)
            h = h @ blk["mlp"]["w2"] + blk["mlp"]["b2"]
            h = _maybe_bn(h, blk["mlp"], "bn4")
            x = shortcut + h
        if stage["merge"] is not None:
            b, hh, ww, c = x.shape
            x = x.reshape(b, hh // 2, 2, ww // 2, 2, c)
            x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, hh // 2, ww // 2, 4 * c)
            x = _maybe_bn(x, stage["merge"], "bn")
            x = x @ stage["merge"]["w"] + stage["merge"]["b"]

    x = _maybe_bn(x, params["head"], "bn")
    x = x.mean(axis=(1, 2))                 # global average pool
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# Fixed path (Pallas kernels; fused + quantised params only)
# ---------------------------------------------------------------------------

def _linear_fixed(x2d, wq, bq):
    """(R, K) Q7.8 @ (K, N) Q3.12 + bias -> (R, N) Q7.8 via the MMU kernel.

    Product accumulates at Q*.20; write-back requantises by WEIGHT_FRAC.
    Bias is added post-requantisation in Q7.8 with saturation — the
    accelerator's bias buffer feeds the accumulation module's output stage;
    `rust/src/accel/mmu.rs` matches this exactly."""
    a, b, n = mmu.pad_operands(x2d, wq)
    out = mmu.matmul_fixed(a, b, rshift=fp.WEIGHT_FRAC)[: x2d.shape[0], :n]
    return fp.sat16(out + bq[None, :])


def _matmul_fixed_batched(a3, b3, rshift: int):
    """vmap'd MMU over a leading (window*head) axis, with tile padding."""
    bsz, r, k = a3.shape
    n = b3.shape[2]
    rp, kp, np_ = (-r) % mmu.TILE_M, (-k) % mmu.TILE_K, (-n) % mmu.TILE_N
    a3 = jnp.pad(a3, ((0, 0), (0, rp), (0, kp)))
    b3 = jnp.pad(b3, ((0, 0), (0, kp), (0, np_)))
    out = jax.vmap(lambda a, b: mmu.matmul_fixed(a, b, rshift=rshift))(a3, b3)
    return out[:, :r, :n]


def _attention_fixed(xw, attn_q, nh: int, mask_q, m: int):
    """Fixed-point W-MSA/SW-MSA over windowed tokens (B_, N, C) int32."""
    b_, n, c = xw.shape
    dh = c // nh
    x2d = xw.reshape(b_ * n, c)
    qkv = _linear_fixed(x2d, attn_q["wqkv"], attn_q["bqkv"])
    qkv = qkv.reshape(b_, n, 3, nh, dh).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]                    # (B_, nh, N, dh)
    qf = q.reshape(b_ * nh, n, dh)
    kft = k.reshape(b_ * nh, n, dh).transpose(0, 2, 1)  # K^T — paper's
    # zero-padded expansion case (§IV.B / §V.A): N=49 pads to 64 columns.
    scores = _matmul_fixed_batched(qf, kft, fp.ACC_FRAC - fp.DATA_FRAC)
    scores = scores.reshape(b_, nh, n, n)
    idx = relative_position_index(m)
    bias_q = attn_q["rel_bias_q"][idx.reshape(-1)].reshape(n, n, nh)
    scores = fp.sat16(scores + bias_q.transpose(2, 0, 1)[None])
    if mask_q is not None:
        nw = mask_q.shape[0]
        scores = scores.reshape(b_ // nw, nw, nh, n, n) + mask_q[None, :, None]
        scores = fp.sat16(scores).reshape(b_, nh, n, n)
    probs = softmax_k.softmax_rows(
        scores.reshape(b_ * nh * n, n))                 # Q0.15
    probs = probs.reshape(b_ * nh, n, n)
    vf = v.reshape(b_ * nh, n, dh)
    # probs(Q0.15) @ v(Q7.8): accumulator Q*.23, requantise >> 15 -> Q7.8
    out = _matmul_fixed_batched(probs, vf, fp.PROB_FRAC)
    out = out.reshape(b_, nh, n, dh).transpose(0, 2, 1, 3).reshape(b_ * n, c)
    out = _linear_fixed(out, attn_q["wproj"], attn_q["bproj"])
    return out.reshape(b_, n, c)


def forward_fixed(cfg: SwinConfig, qparams: dict, images) -> jnp.ndarray:
    """(B, H, W, 3) float32 in [0,1] -> (B, num_classes) logits Q7.8 int32.

    The whole datapath after input quantisation is integer; this function is
    the bit-exact twin of `accel::sim::Simulator::run_image` in Rust."""
    m = cfg.window

    def one(img):
        x = fp.quantize(img[None])                      # (1, H, W, 3) Q7.8
        t = patch_embed_tokens(x, cfg.patch_size)       # (1, Hp, Wp, 48)
        _, hp, wp, pk = t.shape
        x = _linear_fixed(t.reshape(hp * wp, pk),
                          qparams["patch_embed"]["wq"],
                          qparams["patch_embed"]["bq"])
        x = x.reshape(1, hp, wp, cfg.embed_dim)

        for s, stage in enumerate(qparams["stages"]):
            res = cfg.stage_resolution(s)
            nh = cfg.num_heads[s]
            for i, blk in enumerate(stage["blocks"]):
                shift = 0 if (i % 2 == 0 or res <= m) else m // 2
                shortcut = x
                h = x
                if shift:
                    h = jnp.roll(h, (-shift, -shift), axis=(1, 2))
                hw = window_partition(h, m)
                mask = shift_attn_mask(res, res, m, shift)
                mask_q = (None if mask is None else
                          jnp.asarray(np.round(mask * (1 << fp.DATA_FRAC))
                                      .astype(np.int32)))
                hw = _attention_fixed(hw, blk["attn"], nh, mask_q, m)
                h = window_reverse(hw, m, res, res)
                if shift:
                    h = jnp.roll(h, (shift, shift), axis=(1, 2))
                x = fp.sat16(shortcut + h)              # shortcut adder
                shortcut = x
                hw2 = x.reshape(res * res, -1)
                h = _linear_fixed(hw2, blk["mlp"]["w1q"], blk["mlp"]["b1q"])
                h = gelu_k.gelu_rows(h)
                h = _linear_fixed(h, blk["mlp"]["w2q"], blk["mlp"]["b2q"])
                x = fp.sat16(shortcut + h.reshape(x.shape))
            if stage["merge"] is not None:
                b, hh, ww, c = x.shape
                x = x.reshape(b, hh // 2, 2, ww // 2, 2, c)
                x = x.transpose(0, 1, 3, 2, 4, 5).reshape(hh // 2 * ww // 2, 4 * c)
                x = _linear_fixed(x, stage["merge"]["wq"], stage["merge"]["bq"])
                x = x.reshape(b, hh // 2, ww // 2, 2 * c)

        # GAP: sum then multiply by round(2^15 / N) and >> 15 (fixed mean).
        ntok = x.shape[1] * x.shape[2]
        inv = int(round((1 << 15) / ntok))
        tot = jnp.sum(x.reshape(ntok, -1).astype(jnp.int32), axis=0)
        pooled = fp.sat16((tot * inv + (1 << 14)) >> 15)[None]  # (1, Df)
        logits = _linear_fixed(pooled, qparams["head"]["wq"],
                               qparams["head"]["bq"])
        return logits[0]

    return jax.vmap(one)(images)
