"""Table II substitute: LN vs BN in a micro Swin at laptop scale.

The paper trains Swin-T/S/B on ImageNet-1K for 300 epochs on 8x RTX 4090 to
show that replacing LN with BN (plus [17]'s extra BN between the FFN
linears) costs <1% top-1.  We have neither the dataset nor the GPUs
(repro band 0/5), so per DESIGN.md §5.2 we reproduce the *mechanism* at
micro scale:

  1. `ln`        — baseline Swin-micro with LayerNorm
  2. `bn_naive`  — LN swapped for BN with NO extra FFN BN ([17] reports
                   instability/collapse; we measure gradient-norm spikes
                   and final accuracy)
  3. `bn_extra`  — the paper's scheme (Fig. 2): BN everywhere + extra BN
                   after each FFN linear

on a synthetic 10-class 56x56 image task (class templates + noise), with
identical budgets.  Expected shape (mirrors Table II): bn_extra within ~1%
of ln; bn_naive degraded and/or unstable.

Run: `python -m experiments.ln_vs_bn --steps 400 --out ../artifacts/table2.json`
"""

from __future__ import annotations

import argparse
import functools
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

import sys, os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model as m
from compile.configs import MICRO
from compile.kernels import ref

CFG = MICRO
NUM_CLASSES = CFG.num_classes


# ---------------------------------------------------------------------------
# Synthetic dataset: class templates at multiple spatial scales + noise.
# Classes are separable but not trivially (noise sigma comparable to signal).
# ---------------------------------------------------------------------------

def make_dataset(key, n_train: int = 2048, n_test: int = 512,
                 sigma: float = 0.8):
    kt, ka, kb = jax.random.split(key, 3)
    templates = jax.random.normal(kt, (NUM_CLASSES, CFG.img_size,
                                       CFG.img_size, 3)) * 0.5

    def sample(k, n):
        k1, k2, k3 = jax.random.split(k, 3)
        y = jax.random.randint(k1, (n,), 0, NUM_CLASSES)
        amp = 0.5 + jax.random.uniform(k2, (n, 1, 1, 1))
        x = templates[y] * amp + sigma * jax.random.normal(
            k3, (n, CFG.img_size, CFG.img_size, 3))
        return x, y

    xtr, ytr = sample(ka, n_train)
    xte, yte = sample(kb, n_test)
    return (xtr, ytr), (xte, yte)


# ---------------------------------------------------------------------------
# Trainable micro Swin with switchable normalisation
# ---------------------------------------------------------------------------

def _norm_init(dim):
    return {"gamma": jnp.ones((dim,)), "beta": jnp.zeros((dim,))}


def init_train_params(key, mode: str):
    """Reuses model.init_params's linear tree, replacing BN stat dicts with
    trainable affine norms (stats are computed live)."""
    p = m.init_params(CFG, key)

    def strip(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(v, dict) and set(v) == {"gamma", "beta",
                                                      "mean", "var"}:
                    out[k] = _norm_init(v["gamma"].shape[0])
                else:
                    out[k] = strip(v)
            return out
        if isinstance(node, list):
            return [strip(v) for v in node]
        return node

    p = strip(p)
    if mode != "bn_extra":
        # bn3/bn4 (the extra FFN norms) exist only in the paper's scheme;
        # ln and bn_naive use the original Swin block (norm1/norm2 only).
        for stage in p["stages"]:
            for blk in stage["blocks"]:
                blk["mlp"].pop("bn3")
                blk["mlp"].pop("bn4")
    return p


def apply_norm(x, prm, mode: str, train: bool):
    """x: (..., C). ln: per-sample last-dim; bn*: per-channel batch stats."""
    if mode == "ln":
        mu = x.mean(-1, keepdims=True)
        var = x.var(-1, keepdims=True)
    else:
        axes = tuple(range(x.ndim - 1))
        mu = x.mean(axes, keepdims=True)
        var = x.var(axes, keepdims=True)
    xn = (x - mu) / jnp.sqrt(var + 1e-5)
    return xn * prm["gamma"] + prm["beta"]


def forward_train(params, images, mode: str, train: bool = True):
    mm = CFG.window
    x = m.patch_embed_tokens(images, CFG.patch_size)
    x = x @ params["patch_embed"]["w"] + params["patch_embed"]["b"]
    x = apply_norm(x, params["patch_embed"]["bn"], mode, train)
    for s, stage in enumerate(params["stages"]):
        res = CFG.stage_resolution(s)
        nh = CFG.num_heads[s]
        for i, blk in enumerate(stage["blocks"]):
            shift = 0 if (i % 2 == 0 or res <= mm) else mm // 2
            shortcut = x
            h = apply_norm(x, blk["bn1"], mode, train)
            if shift:
                h = jnp.roll(h, (-shift, -shift), axis=(1, 2))
            hw = m.window_partition(h, mm)
            mask = m.shift_attn_mask(res, res, mm, shift)
            mask = None if mask is None else jnp.asarray(mask)
            hw = m._attention_float(hw, blk["attn"], nh, mask,
                                    approx=False, fused=False)
            h = m.window_reverse(hw, mm, res, res)
            if shift:
                h = jnp.roll(h, (shift, shift), axis=(1, 2))
            x = shortcut + h
            shortcut = x
            h = apply_norm(x, blk["bn2"], mode, train)
            h = h @ blk["mlp"]["w1"] + blk["mlp"]["b1"]
            if "bn3" in blk["mlp"]:
                h = apply_norm(h, blk["mlp"]["bn3"], mode, train)
            h = ref.gelu_exact(h)
            h = h @ blk["mlp"]["w2"] + blk["mlp"]["b2"]
            if "bn4" in blk["mlp"]:
                h = apply_norm(h, blk["mlp"]["bn4"], mode, train)
            x = shortcut + h
        if stage["merge"] is not None:
            b, hh, ww, c = x.shape
            x = x.reshape(b, hh // 2, 2, ww // 2, 2, c)
            x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, hh // 2, ww // 2,
                                                      4 * c)
            x = apply_norm(x, stage["merge"]["bn"], mode, train)
            x = x @ stage["merge"]["w"] + stage["merge"]["b"]
    x = apply_norm(x, params["head"]["bn"], mode, train)
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# Hand-rolled Adam (no optax dependency in the image)
# ---------------------------------------------------------------------------

def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8,
              wd=0.05):
    t = state["t"] + 1
    mm = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g,
                                state["m"], grads)
    vv = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g,
                                state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, a, b):
        return p - lr * ((a / bc1) / (jnp.sqrt(b / bc2) + eps) + wd * p)

    params = jax.tree_util.tree_map(upd, params, mm, vv)
    return params, {"m": mm, "v": vv, "t": t}


def loss_fn(params, x, y, mode):
    logits = forward_train(params, x, mode, train=True)
    logp = jax.nn.log_softmax(logits)
    return -logp[jnp.arange(y.shape[0]), y].mean()


def accuracy(params, x, y, mode, batch: int = 128):
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = forward_train(params, x[i:i + batch], mode, train=False)
        correct += int((logits.argmax(-1) == y[i:i + batch]).sum())
    return correct / x.shape[0]


def train_mode(mode: str, data, steps: int, batch: int, lr: float,
               warmup: int, seed: int):
    (xtr, ytr), (xte, yte) = data
    params = init_train_params(jax.random.PRNGKey(seed), mode)
    opt = adam_init(params)
    grad_fn = jax.jit(jax.value_and_grad(functools.partial(loss_fn,
                                                           mode=mode)))
    key = jax.random.PRNGKey(seed + 1)
    losses, grad_norms = [], []
    t0 = time.time()
    for step in range(steps):
        key, ks = jax.random.split(key)
        idx = jax.random.randint(ks, (batch,), 0, xtr.shape[0])
        # cosine schedule with linear warmup (paper's recipe, scaled down)
        if step < warmup:
            cur_lr = lr * (step + 1) / warmup
        else:
            prog = (step - warmup) / max(1, steps - warmup)
            cur_lr = lr * 0.5 * (1 + math.cos(math.pi * prog))
        loss, grads = grad_fn(params, xtr[idx], ytr[idx])
        gn = float(jnp.sqrt(sum(jnp.sum(g * g) for g in
                                jax.tree_util.tree_leaves(grads))))
        params, opt = adam_step(params, grads, opt, cur_lr)
        losses.append(float(loss))
        grad_norms.append(gn)
        if step % 50 == 0:
            print(f"  [{mode}] step {step:4d} loss {float(loss):.4f} "
                  f"gnorm {gn:.2f} ({time.time() - t0:.0f}s)")
    acc = accuracy(params, xte, yte, mode)
    diverged = any(not math.isfinite(l) for l in losses)
    return {
        "mode": mode,
        "final_loss": losses[-1],
        "test_acc": acc,
        "diverged": diverged,
        "max_grad_norm": max(grad_norms),
        "grad_norm_p99": float(np.percentile(grad_norms, 99)),
        "loss_curve": losses[:: max(1, len(losses) // 100)],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="../artifacts/table2.json")
    args = ap.parse_args()

    data = make_dataset(jax.random.PRNGKey(42))
    results = {}
    for mode in ("ln", "bn_naive", "bn_extra"):
        print(f"training {mode} ...")
        results[mode] = train_mode(mode, data, args.steps, args.batch,
                                   args.lr, args.warmup, args.seed)
        print(f"  -> acc {results[mode]['test_acc']:.3f} "
              f"max gnorm {results[mode]['max_grad_norm']:.1f}")

    # Paper reference rows (ImageNet-1K, carried for comparison)
    results["paper_reference"] = {
        "swin_t": {"ln": 0.813, "bn_17": 0.809, "ours_bn": 0.807},
        "swin_s": {"ln": 0.830, "bn_17": 0.828, "ours_bn": 0.827},
        "swin_b": {"ln": 0.855, "bn_17": 0.831, "ours_bn": 0.828},
    }
    delta = results["ln"]["test_acc"] - results["bn_extra"]["test_acc"]
    results["summary"] = {
        "bn_extra_delta_vs_ln": delta,
        "shape_holds": bool(delta < 0.02),
    }
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"\nTable II (substitute) -> {args.out}")
    print(f"  LN acc       {results['ln']['test_acc']:.3f}")
    print(f"  BN naive acc {results['bn_naive']['test_acc']:.3f} "
          f"(max gnorm {results['bn_naive']['max_grad_norm']:.1f})")
    print(f"  BN extra acc {results['bn_extra']['test_acc']:.3f} "
          f"(delta vs LN {delta:+.3f})")


if __name__ == "__main__":
    main()
