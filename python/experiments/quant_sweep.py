"""Quantisation sweep (paper §V.C counterpart): end-to-end effect of the
weight Q-format on the micro model's outputs.

The paper asserts 16-bit fixed "without any noticeable loss in precision"
but reports no sweep. Here we quantise the fused micro-Swin at several
weight fractional widths, run the full fixed-point datapath, and report
logit RMSE vs the float model plus top-1 agreement on a batch of synthetic
images — the data behind choosing Q3.12 weights / Q7.8 activations.

Run: `python -m experiments.quant_sweep --out ../artifacts/quant_sweep.json`
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

import sys, os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import fixedpoint as fp
from compile import fusion, model
from compile.configs import MICRO


def quantize_with(fused, wfrac: int):
    """Re-quantise the fused tree at a given weight frac (biases stay Q7.8)."""
    orig = fp.WEIGHT_FRAC
    try:
        fp.WEIGHT_FRAC = wfrac  # fusion._qw reads it at call time
        return fusion.quantize_fused(MICRO, fused)
    finally:
        fp.WEIGHT_FRAC = orig


def forward_fixed_with(q, imgs, wfrac: int):
    orig = fp.WEIGHT_FRAC
    try:
        fp.WEIGHT_FRAC = wfrac  # _linear_fixed's requant shift
        return model.forward_fixed(MICRO, q, imgs)
    finally:
        fp.WEIGHT_FRAC = orig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--images", type=int, default=8)
    ap.add_argument("--out", default="../artifacts/quant_sweep.json")
    args = ap.parse_args()

    params = model.init_params(MICRO, jax.random.PRNGKey(0))
    params = model.randomize_bn_stats(params, jax.random.PRNGKey(1))
    fused = fusion.fuse_params(MICRO, params)
    imgs = jax.random.uniform(jax.random.PRNGKey(5),
                              (args.images, 56, 56, 3))
    ref = np.asarray(model.forward_float(MICRO, fused, imgs))
    ref_top1 = ref.argmax(-1)

    results = {}
    ulp = 1.0 / 256.0  # output grid of the Q7.8 datapath
    for wfrac in (6, 8, 10, 12, 14):
        q = quantize_with(fused, wfrac)
        logits = np.asarray(forward_fixed_with(q, imgs, wfrac)) / 256.0
        rmse = float(np.sqrt(((logits - ref) ** 2).mean()))
        # untrained-logit margins sit below the Q7.8 output ulp, so exact
        # argmax comparison only measures tie-breaking noise; instead ask
        # whether the fixed path's pick is within one output ulp of the
        # float maximum (i.e. indistinguishable at datapath precision)
        pick = logits.argmax(-1)
        near_top = float(np.mean(
            ref.max(-1) - ref[np.arange(ref.shape[0]), pick] <= ulp))
        exact = float((pick == ref_top1).mean())
        results[f"Q{15-wfrac}.{wfrac}"] = {
            "wfrac": wfrac, "logit_rmse": rmse,
            "top1_within_ulp": near_top, "top1_exact": exact,
        }
        print(f"weights Q{15-wfrac}.{wfrac}: logit RMSE {rmse:.5f}  "
              f"top-1-within-ulp {near_top:.2f} (exact {exact:.2f})")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
