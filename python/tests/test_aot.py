"""AOT export contract tests: manifest structure, HLO text integrity
(constants not elided, no BN/f64 on the request path), weight blob
consistency. These run against the checked-out `artifacts/` directory and
skip (loudly) when it has not been built yet.
"""

import json
import os

import numpy as np
import pytest

from compile import hlo_stats

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_lists_all_expected_artifacts(self, manifest):
        names = set(manifest["artifacts"])
        for required in [
            "kernel_mmu.hlo.txt",
            "kernel_softmax.hlo.txt",
            "kernel_gelu.hlo.txt",
            "kernel_gelu_corrected.hlo.txt",
            "swin_micro_fixed_b1.hlo.txt",
        ] + [f"swin_micro_float_b{b}.hlo.txt" for b in (1, 2, 4, 8)]:
            assert required in names, required

    def test_files_exist_and_nonempty(self, manifest):
        for name in manifest["artifacts"]:
            p = os.path.join(ART, name)
            assert os.path.getsize(p) > 1000, name

    def test_fracs_recorded(self, manifest):
        assert manifest["data_frac"] == 8
        assert manifest["weight_frac"] == 12
        assert manifest["prob_frac"] == 15

    def test_serving_artifacts_batch_shapes(self, manifest):
        for b in (1, 2, 4, 8):
            a = manifest["artifacts"][f"swin_micro_float_b{b}.hlo.txt"]
            assert a["input"]["shape"] == [b, 56, 56, 3]
            assert a["output"]["shape"] == [b, 10]


class TestHloText:
    def test_no_elided_constants(self, manifest):
        for name in manifest["artifacts"]:
            with open(os.path.join(ART, name)) as f:
                text = f.read()
            assert "constant({...})" not in text, (
                f"{name}: elided constants would not round-trip")

    def test_no_bn_or_f64_on_request_path(self, manifest):
        for name in manifest["artifacts"]:
            info = hlo_stats.check_artifact(os.path.join(ART, name))
            assert not info["problems"], (name, info["problems"])

    def test_float_model_flops_scale_with_batch(self, manifest):
        # the estimator is a lower-bound heuristic (contracted extents are
        # only recovered when the operand declaration parses); what must
        # hold exactly is linear scaling with batch size
        f1 = hlo_stats.check_artifact(
            os.path.join(ART, "swin_micro_float_b1.hlo.txt"))["flops"]
        f4 = hlo_stats.check_artifact(
            os.path.join(ART, "swin_micro_float_b4.hlo.txt"))["flops"]
        assert f1 > 5e6, f1
        assert abs(f4 - 4 * f1) / (4 * f1) < 0.05, (f1, f4)

    def test_op_histogram_shapes(self):
        text = """
HloModule m
ENTRY e {
  a = f32[2,3]{1,0} parameter(0)
  b = f32[3,4]{1,0} parameter(1)
  d = f32[2,4]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT r = f32[2,4]{1,0} add(d, d)
}
"""
        ops = hlo_stats.op_histogram(text)
        assert ops["dot"] == 1
        assert ops["add"] == 1
        assert hlo_stats.flop_estimate(text) == 2 * 2 * 4 * 3


class TestWeightBlob:
    def test_blob_matches_manifest_extent(self):
        with open(os.path.join(ART, "weights_micro_manifest.json")) as f:
            man = json.load(f)
        blob = np.fromfile(os.path.join(ART, "weights_micro.bin"), dtype=np.int16)
        end = max(t["offset"] // 2 + t["len"] for t in man["tensors"])
        assert end == blob.size

    def test_tensor_set_matches_micro_structure(self):
        with open(os.path.join(ART, "weights_micro_manifest.json")) as f:
            man = json.load(f)
        names = {t["name"] for t in man["tensors"]}
        # 4 blocks x (wqkv,bqkv,wproj,bproj,rel_bias_q + w1q,b1q,w2q,b2q)
        blocks = [n for n in names if ".blocks." in n]
        assert len(blocks) == 4 * 9
        assert "stages.0.merge.wq" in names
        assert not any(n.startswith("stages.1.merge") for n in names)

    def test_weights_within_int16(self):
        blob = np.fromfile(os.path.join(ART, "weights_micro.bin"), dtype=np.int16)
        assert blob.size > 0
        # not all-zero, and uses a reasonable spread of the grid
        assert np.abs(blob).max() > 50
