"""Unit + property tests for the fixed-point primitives (paper Eqs. 5-12).

These pin down the *contract* the Rust side re-implements: every tolerance
asserted here is also asserted in rust/src/approx tests, and the bit-level
behaviours (floor shifts, saturation, LUT segments) are cross-checked
bit-exactly through the AOT'd kernels in rust/tests/cross_check.rs.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import fixedpoint as fp


def q8(x):
    return jnp.asarray(np.round(np.asarray(x) * 256).astype(np.int32))


class TestExp2:
    def test_exact_powers(self):
        # 2^k for integer k must be exact (frac = 0 -> PWL hits B[0] = 1.0)
        for k in range(-8, 9):
            v = jnp.array([k << fp.EXP_FRAC], jnp.int32)
            got = fp.exp2_fixed(v, fp.OUT_FRAC)[0]
            want = 2.0 ** k * (1 << fp.OUT_FRAC)
            assert abs(int(got) - want) <= 1, (k, int(got), want)

    def test_pwl_accuracy(self):
        # 8-segment PWL error ~3e-4 rel; for small outputs the Q14 output
        # quantisation floor dominates (ulp/value up to ~2^-8 at f = -6)
        f = np.linspace(-6, 6, 4001)
        v = jnp.asarray(np.round(f * (1 << fp.EXP_FRAC)).astype(np.int32))
        got = np.asarray(fp.exp2_fixed(v, fp.OUT_FRAC)) / (1 << fp.OUT_FRAC)
        rel = np.abs(got - 2.0 ** f) / 2.0 ** f
        assert rel.max() < 8e-3, rel.max()
        # and in [0,1) where no shift applies: PWL error (~3e-4) plus the
        # Q10 input-quantisation floor (~ln2 * 2^-10 ~ 6.8e-4)
        sel = (f >= 0) & (f < 1)
        assert rel[sel].max() < 1.5e-3, rel[sel].max()

    def test_monotonic(self):
        v = jnp.arange(-(8 << fp.EXP_FRAC), 8 << fp.EXP_FRAC, 7, dtype=jnp.int32)
        out = np.asarray(fp.exp2_fixed(v, fp.OUT_FRAC))
        assert np.all(np.diff(out) >= 0)

    def test_underflow_flushes_to_zero_side(self):
        v = jnp.array([-40 << fp.EXP_FRAC], jnp.int32)
        assert int(fp.exp2_fixed(v, fp.OUT_FRAC)[0]) <= 1

    def test_overflow_saturates_via_shift_clamp(self):
        v = jnp.array([40 << fp.EXP_FRAC], jnp.int32)
        got = int(fp.exp2_fixed(v, fp.OUT_FRAC)[0])
        assert got == int(fp.exp2_fixed(
            jnp.array([fp.EXP2_SHIFT_MAX << fp.EXP_FRAC], jnp.int32),
            fp.OUT_FRAC)[0])

    @given(st.integers(min_value=-(12 << 10), max_value=12 << 10))
    @settings(max_examples=200, deadline=None)
    def test_hypothesis_rel_error(self, vi):
        got = int(fp.exp2_fixed(jnp.array([vi], jnp.int32), fp.OUT_FRAC)[0])
        want = 2.0 ** (vi / 1024.0) * (1 << fp.OUT_FRAC)
        # tolerance: PWL error (8e-3 rel) plus the output quantisation
        # floor after the barrel shift (~1.5 ulp at the output scale)
        assert abs(got - want) <= max(8e-3 * want, 1.5)


class TestLod:
    def test_known_values(self):
        f = jnp.array([1, 2, 3, 4, 255, 256, (1 << 30) - 1, 1 << 30], jnp.int32)
        got = np.asarray(fp.lod(f))
        assert list(got) == [0, 1, 1, 2, 7, 8, 29, 30]

    def test_zero_and_negative(self):
        assert int(fp.lod(jnp.array([0], jnp.int32))[0]) == 0

    @given(st.integers(min_value=1, max_value=(1 << 31) - 1))
    @settings(max_examples=200, deadline=None)
    def test_hypothesis_matches_bitlength(self, f):
        assert int(fp.lod(jnp.array([f], jnp.int32))[0]) == f.bit_length() - 1


class TestLog2Approx:
    def test_powers_of_two_exact(self):
        for k in range(0, 20):
            got = int(fp.log2_approx(jnp.array([1 << k], jnp.int32), 0)[0])
            assert got == k << fp.EXP_FRAC

    def test_max_error_bound(self):
        # log2(m) - (m-1) peaks at m = 1/ln2 ~ 1.4427: error ~ 0.0861
        f = np.arange(1, 1 << 16, 13, dtype=np.int64)
        got = np.asarray(fp.log2_approx(jnp.asarray(f, jnp.int32), 0))
        want = np.log2(f) * (1 << fp.EXP_FRAC)
        err = np.abs(got - want) / (1 << fp.EXP_FRAC)
        assert err.max() < 0.0875, err.max()  # Eq. 12 intrinsic bound


class TestDivision:
    @given(st.integers(min_value=1, max_value=1 << 20),
           st.integers(min_value=1, max_value=1 << 20))
    @settings(max_examples=200, deadline=None)
    def test_quotient_within_lod_bound(self, a, b):
        e = fp.div_exponent(jnp.array([a], jnp.int32), 0,
                            jnp.array([b], jnp.int32), 0)
        got = 2.0 ** (int(e[0]) / (1 << fp.EXP_FRAC))
        want = a / b
        # Eq. 12: each log2 off by <= 0.0861 -> quotient within 2^0.1722
        assert got / want < 2 ** 0.18 and want / got < 2 ** 0.18


class TestSoftmaxFixed:
    def test_vs_exact(self):
        rs = np.random.RandomState(0)
        x = rs.randn(64, 49) * 3
        out = np.asarray(fp.softmax_fixed(q8(x))) / (1 << fp.PROB_FRAC)
        e = np.exp(x - x.max(-1, keepdims=True))
        want = e / e.sum(-1, keepdims=True)
        assert np.abs(out - want).max() < 0.05

    def test_rows_sum_near_one(self):
        rs = np.random.RandomState(1)
        x = rs.randn(32, 49) * 5
        out = np.asarray(fp.softmax_fixed(q8(x))) / (1 << fp.PROB_FRAC)
        sums = out.sum(-1)
        assert np.all(sums > 0.85) and np.all(sums < 1.15)

    def test_shift_invariance(self):
        # softmax(x) == softmax(x + c): the FMU max-subtract guarantees it
        rs = np.random.RandomState(2)
        x = q8(rs.randn(8, 49))
        a = np.asarray(fp.softmax_fixed(x))
        b = np.asarray(fp.softmax_fixed(x + (7 << fp.DATA_FRAC)))
        assert np.array_equal(a, b)

    def test_argmax_preserved(self):
        rs = np.random.RandomState(3)
        x = rs.randn(64, 49) * 4
        out = np.asarray(fp.softmax_fixed(q8(x)))
        assert np.all(out.argmax(-1) == x.argmax(-1))

    def test_extreme_logits_one_hot(self):
        x = np.full((1, 49), -20.0)
        x[0, 7] = 20.0
        out = np.asarray(fp.softmax_fixed(q8(x))) / (1 << fp.PROB_FRAC)
        assert out[0, 7] > 0.95 and np.delete(out[0], 7).max() < 0.01

    @given(st.integers(min_value=2, max_value=64))
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_row_widths(self, n):
        rs = np.random.RandomState(n)
        x = rs.randn(4, n) * 2
        out = np.asarray(fp.softmax_fixed(q8(x))) / (1 << fp.PROB_FRAC)
        assert np.all(out >= 0) and np.abs(out.sum(-1) - 1).max() < 0.15


class TestGeluFixed:
    def test_vs_exact_small_x(self):
        x = np.linspace(-1.5, 1.5, 201)
        g = np.asarray(fp.gelu_fixed(q8(x))) / 256.0
        want = 0.5 * x * (1 + np.tanh(math.sqrt(2 / math.pi)
                                      * (x + 0.044715 * x ** 3)))
        assert np.abs(g - want).max() < 0.06

    def test_lod_ripple_bound_large_x(self):
        # For x >> 0, gelu(x) -> x; Eq. 12 ripple bounds error to ~6% rel
        x = np.linspace(2, 7.5, 100)
        g = np.asarray(fp.gelu_fixed(q8(x))) / 256.0
        assert (np.abs(g - x) / x).max() < 0.07

    def test_negative_tail_to_zero(self):
        x = np.linspace(-8, -4, 40)
        g = np.asarray(fp.gelu_fixed(q8(x))) / 256.0
        assert np.abs(g).max() < 0.02

    def test_zero(self):
        assert int(fp.gelu_fixed(jnp.array([0], jnp.int32))[0]) == 0

    def test_odd_ish_shape(self):
        # gelu(x) + gelu(-x) == x (exact identity); approx within tolerance
        x = np.linspace(0.1, 4, 50)
        gp = np.asarray(fp.gelu_fixed(q8(x))) / 256.0
        gn = np.asarray(fp.gelu_fixed(q8(-x))) / 256.0
        # LOD ripple on each term is ~6%: bound the sum accordingly
        assert np.abs((gp + gn) - x).max() < 0.07 * x.max() + 0.12

    def test_monotone_nonneg_region(self):
        x = np.linspace(0, 7.9, 400)
        g = np.asarray(fp.gelu_fixed(q8(x)))
        assert np.all(np.diff(g) >= -int(0.08 * 256))  # ripple tolerance

    @given(st.floats(min_value=-7.9, max_value=7.9, allow_nan=False))
    @settings(max_examples=200, deadline=None)
    def test_hypothesis_pointwise(self, xv):
        g = int(fp.gelu_fixed(q8(np.array([xv])))[0]) / 256.0
        want = 0.5 * xv * (1 + math.tanh(math.sqrt(2 / math.pi)
                                         * (xv + 0.044715 * xv ** 3)))
        assert abs(g - want) <= 0.08 * max(1.0, abs(want))


class TestRequantize:
    def test_round_half_up(self):
        # (-129 + 128) >> 8 == (-1) >> 8 == -1: arithmetic floor shift
        acc = jnp.array([128, 127, -128, -129, 384], jnp.int32)
        out = np.asarray(fp.requantize_acc(acc, 8))
        assert list(out) == [1, 0, 0, -1, 2]

    def test_saturation(self):
        acc = jnp.array([1 << 30, -(1 << 30)], jnp.int32)
        out = np.asarray(fp.requantize_acc(acc, 8))
        assert list(out) == [fp.I16_MAX, fp.I16_MIN]


class TestShiftAddConstants:
    def test_log2e_value(self):
        x = jnp.array([1 << 12], jnp.int32)
        assert int(fp.mul_log2e(x)[0]) == int((1 + 0.5 - 0.0625) * (1 << 12))

    def test_gelu_poly_constant(self):
        x3 = jnp.array([1 << 12], jnp.int32)
        assert int(fp.mul_gelu_cubic(x3)[0]) == int(0.046875 * (1 << 12))

    def test_corrected_cubic_closer(self):
        x3 = jnp.array([1 << 14], jnp.int32)
        paper = int(fp.mul_gelu_cubic(x3)[0]) / (1 << 14)
        corr = int(fp.mul_gelu_cubic_corrected(x3)[0]) / (1 << 14)
        assert abs(corr - 0.044715) < abs(paper - 0.044715)

    def test_neg2log2e(self):
        u = jnp.array([1 << 12], jnp.int32)
        assert int(fp.mul_neg2log2e_sqrt2pi(u)[0]) == -int(2.3125 * (1 << 12))
