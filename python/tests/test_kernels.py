"""Pallas kernel tests: MMU / SCU / GCU vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value ranges; exactness assertions pin the
kernels to the integer golden models in `fixedpoint.py` (which rust
re-implements), and tolerance assertions pin them to float truth.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import fixedpoint as fp
from compile.kernels import gelu as gelu_k
from compile.kernels import mmu
from compile.kernels import ref
from compile.kernels import softmax as softmax_k


def q8(x):
    return jnp.asarray(np.round(np.asarray(x) * 256).astype(np.int32))


class TestMmuKernel:
    def test_exact_vs_integer_matmul(self):
        rs = np.random.RandomState(0)
        a = jnp.asarray(rs.randint(-2000, 2000, (49, 96)), jnp.int32)
        b = jnp.asarray(rs.randint(-2000, 2000, (96, 64)), jnp.int32)
        out = mmu.matmul_fixed(a, b)
        want = fp.requantize_acc(a @ b)
        assert bool(jnp.all(out == want))

    def test_rshift_variants(self):
        rs = np.random.RandomState(1)
        a = jnp.asarray(rs.randint(-1000, 1000, (49, 32)), jnp.int32)
        b = jnp.asarray(rs.randint(-1000, 1000, (32, 32)), jnp.int32)
        for rshift in (8, 12, 15):
            out = mmu.matmul_fixed(a, b, rshift=rshift)
            want = fp.requantize_acc(a @ b, rshift)
            assert bool(jnp.all(out == want)), rshift

    def test_multi_tile_accumulation(self):
        # C_I spanning several c_i tiles exercises the accumulation module
        rs = np.random.RandomState(2)
        a = jnp.asarray(rs.randint(-500, 500, (98, 160)), jnp.int32)
        b = jnp.asarray(rs.randint(-500, 500, (160, 96)), jnp.int32)
        out = mmu.matmul_fixed(a, b)
        assert bool(jnp.all(out == fp.requantize_acc(a @ b)))

    def test_zero_padding_is_invalid_computation_only(self):
        # paper §V.A: padded K^T columns waste cycles but change no outputs
        rs = np.random.RandomState(3)
        a = jnp.asarray(rs.randint(-500, 500, (49, 50)), jnp.int32)
        b = jnp.asarray(rs.randint(-500, 500, (50, 49)), jnp.int32)
        ap, bp, n = mmu.pad_operands(a, b)
        assert ap.shape == (49, 64) and bp.shape == (64, 64)
        out = mmu.matmul_fixed(ap, bp)[:, :n]
        assert bool(jnp.all(out == fp.requantize_acc(a @ b)))

    def test_float_accuracy_through_quantisation(self):
        rs = np.random.RandomState(4)
        af = rs.randn(49, 64).astype(np.float32)
        bf = (0.05 * rs.randn(64, 32)).astype(np.float32)
        aq = fp.quantize(jnp.asarray(af))
        bq = fp.quantize(jnp.asarray(bf), fp.WEIGHT_FRAC)
        out = mmu.matmul_fixed(aq, bq, rshift=fp.WEIGHT_FRAC)
        got = np.asarray(out) / (1 << fp.DATA_FRAC)
        want = af @ bf
        assert np.abs(got - want).max() < 0.05

    def test_vmap_batching(self):
        rs = np.random.RandomState(5)
        a = jnp.asarray(rs.randint(-300, 300, (3, 49, 32)), jnp.int32)
        b = jnp.asarray(rs.randint(-300, 300, (3, 32, 64)), jnp.int32)
        out = jax.vmap(mmu.matmul_fixed)(a, b)
        want = fp.requantize_acc(jnp.einsum("bij,bjk->bik", a, b))
        assert bool(jnp.all(out == want))

    def test_misaligned_raises(self):
        a = jnp.zeros((50, 32), jnp.int32)
        b = jnp.zeros((32, 32), jnp.int32)
        with pytest.raises(AssertionError):
            mmu.matmul_fixed(a, b)

    @given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 3),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_hypothesis_tile_grid(self, mi, ki, ni, seed):
        rs = np.random.RandomState(seed)
        a = jnp.asarray(rs.randint(-400, 400, (49 * mi, 32 * ki)), jnp.int32)
        b = jnp.asarray(rs.randint(-400, 400, (32 * ki, 32 * ni)), jnp.int32)
        out = mmu.matmul_fixed(a, b)
        assert bool(jnp.all(out == fp.requantize_acc(a @ b)))


class TestScuKernel:
    def test_matches_golden_model(self):
        rs = np.random.RandomState(0)
        x = q8(rs.randn(98, 49) * 2)
        out = softmax_k.softmax_rows(x)
        want = fp.softmax_fixed(x, axis=-1)
        assert bool(jnp.all(out == want))

    def test_vs_exact_softmax(self):
        rs = np.random.RandomState(1)
        xf = rs.randn(49, 49).astype(np.float32) * 3
        out = np.asarray(softmax_k.softmax_rows(q8(xf))) / (1 << fp.PROB_FRAC)
        want = np.asarray(ref.softmax_exact(jnp.asarray(xf)))
        assert np.abs(out - want).max() < 0.05

    def test_vs_float_approx_dataflow(self):
        # fixed-point kernel vs the paper dataflow in float: only
        # quantisation error remains
        rs = np.random.RandomState(2)
        xf = rs.randn(49, 49).astype(np.float32) * 2
        out = np.asarray(softmax_k.softmax_rows(q8(xf))) / (1 << fp.PROB_FRAC)
        want = np.asarray(ref.softmax_approx(jnp.asarray(xf / 256 * 256)))
        assert np.abs(out - want).max() < 0.01

    def test_padding_lanes_ignored(self):
        rs = np.random.RandomState(3)
        x = q8(rs.randn(49, 49))
        xp = jnp.pad(x, ((0, 0), (0, 15)), constant_values=12345)
        out_p = softmax_k.softmax_rows(xp, n_valid=49)[:, :49]
        out = softmax_k.softmax_rows(x)
        # NEG_PAD lanes contribute 1 ulp each to the sum: tiny, bounded
        diff = np.abs(np.asarray(out_p) - np.asarray(out)) / (1 << fp.PROB_FRAC)
        assert diff.max() < 2e-3

    def test_row_blocks_partition_correctly(self):
        rs = np.random.RandomState(4)
        x = q8(rs.randn(4 * 49, 49))
        whole = softmax_k.softmax_rows(x, row_block=49)
        single = softmax_k.softmax_rows(x, row_block=4 * 49)
        assert bool(jnp.all(whole == single))

    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([7, 16, 49, 64]))
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_shapes(self, seed, n):
        rs = np.random.RandomState(seed)
        x = q8(rs.randn(8, n) * 3)
        out = np.asarray(softmax_k.softmax_rows(x)) / (1 << fp.PROB_FRAC)
        assert np.all(out >= 0) and np.abs(out.sum(-1) - 1).max() < 0.12


class TestGcuKernel:
    def test_matches_golden_model(self):
        rs = np.random.RandomState(0)
        x = q8(rs.randn(64, 128) * 2)
        out = gelu_k.gelu_rows(x)
        want = fp.gelu_fixed(x)
        assert bool(jnp.all(out == want))

    def test_corrected_matches_golden(self):
        rs = np.random.RandomState(1)
        x = q8(rs.randn(64, 128) * 2)
        out = gelu_k.gelu_rows(x, corrected=True)
        want = fp.gelu_fixed(x, corrected_cubic=True)
        assert bool(jnp.all(out == want))

    def test_vs_exact_gelu(self):
        rs = np.random.RandomState(2)
        xf = (rs.randn(49, 64) * 2).astype(np.float32)
        out = np.asarray(gelu_k.gelu_rows(q8(xf))) / 256.0
        want = np.asarray(ref.gelu_exact(jnp.asarray(xf)))
        rel = np.abs(out - want) / np.maximum(np.abs(want), 0.25)
        assert rel.max() < 0.07

    def test_vs_float_approx_dataflow(self):
        rs = np.random.RandomState(3)
        xf = (rs.randn(49, 64) * 2).astype(np.float32)
        xq = q8(xf)
        out = np.asarray(gelu_k.gelu_rows(xq)) / 256.0
        want = np.asarray(ref.gelu_approx(jnp.asarray(np.asarray(xq) / 256.0)))
        assert np.abs(out - want).max() < 0.02

    @given(st.integers(0, 2 ** 31 - 1),
           st.sampled_from([(49, 32), (98, 64), (64, 128)]))
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_shapes_and_scales(self, seed, shape):
        rs = np.random.RandomState(seed)
        x = q8(rs.randn(*shape) * 3)
        out = gelu_k.gelu_rows(x)
        assert bool(jnp.all(out == fp.gelu_fixed(x)))
