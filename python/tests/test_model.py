"""L2 model tests: window helpers, masks, BN fusion identity (paper Eqs. 2-4),
float-vs-fixed agreement, and shape/structure invariants.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, fixedpoint as fp, fusion, model


@pytest.fixture(scope="module")
def micro_setup():
    cfg = configs.MICRO
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    params = model.randomize_bn_stats(params, jax.random.PRNGKey(1))
    fused = fusion.fuse_params(cfg, params)
    q = fusion.quantize_fused(cfg, fused)
    return cfg, params, fused, q


class TestWindowHelpers:
    @given(st.sampled_from([(14, 14, 7), (28, 28, 7), (8, 8, 4), (56, 56, 7)]))
    @settings(max_examples=8, deadline=None)
    def test_partition_reverse_roundtrip(self, hwm):
        h, w, m = hwm
        x = jnp.arange(2 * h * w * 3, dtype=jnp.float32).reshape(2, h, w, 3)
        back = model.window_reverse(model.window_partition(x, m), m, h, w)
        assert bool(jnp.all(back == x))

    def test_partition_groups_local_pixels(self):
        # every window must contain exactly one m x m spatial patch
        m = 7
        x = jnp.arange(14 * 14, dtype=jnp.float32).reshape(1, 14, 14, 1)
        win = np.asarray(model.window_partition(x, m))[..., 0]
        first = win[0].reshape(m, m)
        want = np.asarray(x)[0, :7, :7, 0]
        assert np.array_equal(first, want)

    def test_relative_position_index_properties(self):
        idx = model.relative_position_index(7)
        assert idx.shape == (49, 49)
        assert idx.min() >= 0 and idx.max() < 13 * 13
        # symmetric pairs map to mirrored table entries; diagonal constant
        assert len(set(idx[np.arange(49), np.arange(49)])) == 1

    def test_shift_mask_structure(self):
        mask = model.shift_attn_mask(14, 14, 7, 3)
        assert mask.shape == (4, 49, 49)
        # window 0 (top-left) is uncut: no masking at all
        assert np.all(mask[0] == 0)
        # cut windows mask some pairs both directions (symmetric pattern)
        assert (mask[1] < 0).any()
        assert np.array_equal(mask[1], mask[1].T)

    def test_no_mask_for_unshifted(self):
        assert model.shift_attn_mask(14, 14, 7, 0) is None

    def test_patch_embed_tokens_order(self):
        # flattening must be (ph, pw, chan) so the matmul weight layout
        # matches rust/src/model/weights.rs
        x = jnp.arange(8 * 8 * 3, dtype=jnp.float32).reshape(1, 8, 8, 3)
        t = np.asarray(model.patch_embed_tokens(x, 4))
        assert t.shape == (1, 2, 2, 48)
        want = np.asarray(x)[0, :4, :4, :].reshape(-1)
        assert np.array_equal(t[0, 0, 0], want)


class TestFusion:
    def test_fused_forward_matches_unfused(self, micro_setup):
        cfg, params, fused, _ = micro_setup
        imgs = jax.random.uniform(jax.random.PRNGKey(7), (2, 56, 56, 3))
        a = model.forward_float(cfg, params, imgs)
        b = model.forward_float(cfg, fused, imgs)
        assert float(jnp.abs(a - b).max()) < 1e-5

    def test_fusion_removes_all_bn(self, micro_setup):
        _, _, fused, _ = micro_setup

        def no_bn(node):
            if isinstance(node, dict):
                assert set(node) != {"gamma", "beta", "mean", "var"}
                for v in node.values():
                    no_bn(v)
            elif isinstance(node, list):
                for v in node:
                    no_bn(v)

        no_bn(fused)

    def test_identity_bn_fusion_is_noop(self):
        cfg = configs.MICRO
        params = model.init_params(cfg, jax.random.PRNGKey(3))
        fused = fusion.fuse_params(cfg, params)
        blk = params["stages"][0]["blocks"][0]
        fblk = fused["stages"][0]["blocks"][0]
        # identity BN stats + q-scaling only: wqkv K/V thirds unchanged
        c = cfg.embed_dim
        assert float(jnp.abs(blk["attn"]["wqkv"][:, c:]
                             - fblk["attn"]["wqkv"][:, c:]).max()) < 1e-6

    def test_pre_fuse_algebra(self):
        # y = BN(x) @ W + b  must equal  x @ W' + b'
        key = jax.random.PRNGKey(11)
        bn = {"gamma": jnp.array([1.5, 0.5]), "beta": jnp.array([0.1, -0.2]),
              "mean": jnp.array([0.3, -0.4]), "var": jnp.array([2.0, 0.5])}
        w = jax.random.normal(key, (2, 3))
        b = jnp.array([0.5, -0.5, 0.0])
        x = jax.random.normal(jax.random.PRNGKey(12), (5, 2))
        w2, b2 = fusion._pre_fuse(bn, w, b)
        inv = bn["gamma"] / jnp.sqrt(bn["var"] + fusion.EPS)
        want = ((x - bn["mean"]) * inv + bn["beta"]) @ w + b
        assert float(jnp.abs(x @ w2 + b2 - want).max()) < 1e-5

    def test_post_fuse_algebra(self):
        bn = {"gamma": jnp.array([1.5, 0.5, 2.0]),
              "beta": jnp.array([0.1, -0.2, 0.0]),
              "mean": jnp.array([0.3, -0.4, 1.0]),
              "var": jnp.array([2.0, 0.5, 1.0])}
        w = jax.random.normal(jax.random.PRNGKey(13), (2, 3))
        b = jnp.array([0.5, -0.5, 0.0])
        x = jax.random.normal(jax.random.PRNGKey(14), (5, 2))
        w2, b2 = fusion._post_fuse(bn, w, b)
        inv = bn["gamma"] / jnp.sqrt(bn["var"] + fusion.EPS)
        want = ((x @ w + b) - bn["mean"]) * inv + bn["beta"]
        assert float(jnp.abs(x @ w2 + b2 - want).max()) < 1e-5


class TestFixedForward:
    def test_fixed_matches_float_within_quant_tolerance(self, micro_setup):
        cfg, _, fused, q = micro_setup
        imgs = jax.random.uniform(jax.random.PRNGKey(8), (1, 56, 56, 3))
        lf = model.forward_float(cfg, fused, imgs)
        lq = model.forward_fixed(cfg, q, imgs) / (1 << fp.DATA_FRAC)
        assert float(jnp.abs(lf - lq).max()) < 0.05

    def test_fixed_top1_close_to_float_top(self, micro_setup):
        # with random (untrained) weights the logit margins are tiny, so
        # exact argmax agreement is not guaranteed; instead require the
        # fixed path's argmax to be a near-top float class
        cfg, _, fused, q = micro_setup
        imgs = jax.random.uniform(jax.random.PRNGKey(9), (2, 56, 56, 3))
        lf = model.forward_float(cfg, fused, imgs)
        lq = model.forward_fixed(cfg, q, imgs)
        pick = np.asarray(lq.argmax(-1))
        lf = np.asarray(lf)
        for b in range(2):
            assert lf[b].max() - lf[b, pick[b]] < 0.05

    def test_fixed_deterministic(self, micro_setup):
        cfg, _, _, q = micro_setup
        imgs = jax.random.uniform(jax.random.PRNGKey(10), (1, 56, 56, 3))
        a = model.forward_fixed(cfg, q, imgs)
        b = model.forward_fixed(cfg, q, imgs)
        assert bool(jnp.all(a == b))


class TestWeightExport:
    def test_flatten_deterministic_order(self, micro_setup):
        _, _, _, q = micro_setup
        names = [n for n, _ in fusion.flatten_qtree(q)]
        assert names == sorted(names) or names  # deterministic: fixed order
        assert names[0] == "head.bq"
        assert any(n.startswith("stages.0.blocks.0.attn.wqkv") for n in names)

    def test_roundtrip_bin(self, micro_setup, tmp_path):
        import json
        _, _, _, q = micro_setup
        bin_p = tmp_path / "w.bin"
        man_p = tmp_path / "w.json"
        fusion.write_weights(q, str(bin_p), str(man_p))
        man = json.loads(man_p.read_text())
        blob = np.fromfile(bin_p, dtype=np.int16)
        items = dict(fusion.flatten_qtree(q))
        for t in man["tensors"]:
            arr = blob[t["offset"] // 2: t["offset"] // 2 + t["len"]]
            want = np.asarray(items[t["name"]]).reshape(-1)
            assert np.array_equal(arr, want), t["name"]
