//! End-to-end serving bench: the continuous batcher and fleet router over
//! both [`Engine`] backends — `PjrtEngine` when `artifacts/` and a real
//! PJRT runtime exist, `SimEngine` always — plus the batching-policy
//! ablation (continuous vs the seed's stop-the-world accumulate/flush
//! cycle at equal `max_wait`) and the pipeline-IR launch-cost ablations:
//! cross-unit prefetch vs sequential scheduling units, and warm
//! (cross-launch prefetch, steady-state) vs cold launch costs — the
//! latter both as raw cycle tables and through the heterogeneous fleet
//! experiment (`overlap_interlaunch` on/off).
//!
//! Set `SWIN_BENCH_SHORT=1` for the CI smoke run (fewer requests/points).

use std::path::PathBuf;
use std::time::Duration;

use swin_fpga::accel::pipeline::PipelineSchedule;
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{MICRO, TINY};
use swin_fpga::report::Table;
use swin_fpga::server::router::{
    fleet_capacity_fps, fleet_percentiles, hetero_ts_fleet, percentile, LoadModel, Policy,
    Router,
};
use swin_fpga::server::workload::{classed_arrivals, Arrival};
use swin_fpga::server::{
    run_demo_metrics, run_demo_metrics_sim, BatchMode, BatchPolicy, Engine, Metrics, SimEngine,
};

fn metrics_row(t: &mut Table, label: &str, rate: f64, mode: &str, m: &Metrics) {
    t.row(&[
        label.to_string(),
        format!("{rate:.0}"),
        mode.to_string(),
        format!("{:.1}", m.throughput()),
        format!("{:.2}", m.percentile_ms(0.50)),
        format!("{:.2}", m.percentile_ms(0.95)),
        format!("{:.2}", m.percentile_ms(0.99)),
        format!("{:.0}%", m.occupancy_mean() * 100.0),
        m.queue_depth_max().to_string(),
    ]);
}

fn main() -> anyhow::Result<()> {
    // CI smoke mode: same code paths, fewer requests and load points
    let short = std::env::var("SWIN_BENCH_SHORT").is_ok();
    let n_point = if short { 12 } else { 48 };
    let n_ablate = if short { 16 } else { 64 };
    let n_fleet = if short { 100 } else { 400 };

    let title = format!("e2e serving — continuous batcher, {n_point} requests per point");
    let mut t = Table::new(
        &title,
        &[
            "engine",
            "offered req/s",
            "mode",
            "req/s",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "occupancy",
            "max depth",
        ],
    );

    // --- PJRT backend (skipped gracefully when unavailable) --------------
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        for rate in [20.0, 60.0, 200.0] {
            match run_demo_metrics(&dir, n_point, rate, BatchPolicy::default()) {
                Ok(m) => metrics_row(&mut t, "pjrt(micro)", rate, "continuous", &m),
                Err(e) => {
                    println!("(pjrt rows skipped: {e:#})");
                    break;
                }
            }
        }
    } else {
        println!("(artifacts/ missing — pjrt rows skipped, sim rows follow)");
    }

    // --- Simulated backend (always runs, same serving code path) ---------
    for rate in [200.0, 1_000.0, 4_000.0] {
        let m = run_demo_metrics_sim(
            &MICRO,
            AccelConfig::paper(),
            1.0,
            n_point,
            rate,
            BatchPolicy::default(),
        )?;
        metrics_row(&mut t, "sim(micro)", rate, "continuous", &m);
    }
    println!("{t}");

    // --- pipeline-IR launch-cost ablation (pure model, no serving) -------
    // cold = launch into an idle pipeline; warm = steady-state per-launch
    // increment of a back-to-back queue with cross-launch prefetch
    let mut t = Table::new(
        "launch cycles — sequential units vs pipelined, cold vs warm (swin-t)",
        &["batch", "sequential", "cold", "warm steady", "warm saves"],
    );
    let pipe = PipelineSchedule::for_variant(&TINY, AccelConfig::paper());
    let seq = PipelineSchedule::for_variant(&TINY, AccelConfig::paper().sequential());
    for b in [1usize, 2, 4, 8] {
        let (s, cold, warm) = (
            seq.launch_cycles(b),
            pipe.launch_cycles(b),
            pipe.steady_launch_cycles(b),
        );
        t.row(&[
            b.to_string(),
            s.to_string(),
            cold.to_string(),
            warm.to_string(),
            format!("{:.3}%", (cold - warm) as f64 / cold as f64 * 100.0),
        ]);
    }
    println!("{t}");

    // --- ablation: continuous vs stop-the-world at equal max_wait --------
    let title =
        format!("batching ablation — swin-t sim card (time_scale 0.05), {n_ablate} requests");
    let mut t = Table::new(
        &title,
        &[
            "offered req/s",
            "mode",
            "req/s",
            "p50 ms",
            "p99 ms",
            "occupancy",
        ],
    );
    for rate in [100.0, 400.0, 1_200.0] {
        for mode in [BatchMode::Continuous, BatchMode::StopTheWorld] {
            let m = run_demo_metrics_sim(
                &TINY,
                AccelConfig::paper(),
                0.05,
                n_ablate,
                rate,
                BatchPolicy {
                    max_batch: 32,
                    max_wait: Duration::from_millis(6),
                    mode,
                    ..Default::default()
                },
            )?;
            t.row(&[
                format!("{rate:.0}"),
                match mode {
                    BatchMode::Continuous => "continuous".into(),
                    BatchMode::StopTheWorld => "stop-the-world".into(),
                },
                format!("{:.1}", m.throughput()),
                format!("{:.2}", m.percentile_ms(0.50)),
                format!("{:.2}", m.percentile_ms(0.99)),
                format!("{:.0}%", m.occupancy_mean() * 100.0),
            ]);
        }
    }
    println!("{t}");

    // --- fleet: the same Router over Vec<Box<dyn Engine>> ----------------
    let title = format!("fleet routing over dyn Engine (virtual time, {n_fleet} requests)");
    let mut t = Table::new(&title, &["cards", "offered FPS", "policy", "p50 ms", "p99 ms"]);
    for cards in [1usize, 2, 4] {
        for rate in [30.0, 80.0, 150.0] {
            for policy in [Policy::RoundRobin, Policy::LeastLoaded] {
                let engines: Vec<Box<dyn Engine>> = (0..cards)
                    .map(|i| {
                        Box::new(SimEngine::new(i, &TINY, AccelConfig::paper(), 0.0))
                            as Box<dyn Engine>
                    })
                    .collect();
                let mut r = Router::from_engines(engines, policy);
                let lats = r.run_poisson(n_fleet, rate, 11);
                t.row(&[
                    cards.to_string(),
                    format!("{rate:.0}"),
                    policy.name().into(),
                    format!("{:.1}", percentile(&lats, 0.50)),
                    format!("{:.1}", percentile(&lats, 0.99)),
                ]);
            }
        }
    }
    println!("{t}");

    // --- the PR-3 fleet experiment: per-card batcher queues, backlog- ----
    // --- aware JSQ vs the busy-horizon baseline, bursty mixed-SLO load ---
    let n_exp = if short { 200 } else { 600 };
    let title = format!(
        "per-card batcher fleet — 2x swin-t + 2x swin-s, bursty arrivals, \
         {n_exp} requests (50% interactive)"
    );
    let mut t = Table::new(
        &title,
        &[
            "policy",
            "load signal",
            "launch timing",
            "p50 ms",
            "p99 ms",
            "interactive p99",
            "batch p99",
        ],
    );
    // warm = cross-launch prefetch on (back-to-back launches pay the
    // steady-state cost, backlog priced warm); cold = every launch pays
    // the cold entry (the pre-sequence-IR timing structure)
    let timings = [
        ("cold", AccelConfig::paper().interlaunch(false)),
        ("warm", AccelConfig::paper()),
    ];
    let cap = fleet_capacity_fps(&hetero_ts_fleet(&AccelConfig::paper()));
    let arr = classed_arrivals(
        Arrival::Bursty {
            high: 2.0 * cap,
            burst_s: 0.2,
            gap_s: 0.3,
        },
        n_exp,
        0.5,
        31,
    );
    for policy in [Policy::LeastLoaded, Policy::PowerOfTwo] {
        for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
            for (label, cfg) in &timings {
                let mut r =
                    Router::from_engines(hetero_ts_fleet(cfg), policy).with_load(load);
                let comps = r.run_classed(&arr);
                let [p50, p99, inter_p99, batch_p99] = fleet_percentiles(&comps);
                t.row(&[
                    policy.name().into(),
                    load.name().into(),
                    (*label).into(),
                    format!("{p50:.1}"),
                    format!("{p99:.1}"),
                    format!("{inter_p99:.1}"),
                    format!("{batch_p99:.1}"),
                ]);
            }
        }
    }
    println!("{t}");
    Ok(())
}
