//! End-to-end serving bench: the continuous batcher and fleet router over
//! both [`Engine`] backends — `PjrtEngine` when `artifacts/` and a real
//! PJRT runtime exist, `SimEngine` always — plus the batching-policy
//! ablation (continuous vs the seed's stop-the-world accumulate/flush
//! cycle at equal `max_wait`).

use std::path::PathBuf;
use std::time::Duration;

use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{MICRO, TINY};
use swin_fpga::report::Table;
use swin_fpga::server::router::{percentile, Policy, Router};
use swin_fpga::server::{
    run_demo_metrics, run_demo_metrics_sim, BatchMode, BatchPolicy, Engine, Metrics, SimEngine,
};

fn metrics_row(t: &mut Table, label: &str, rate: f64, mode: &str, m: &Metrics) {
    t.row(&[
        label.to_string(),
        format!("{rate:.0}"),
        mode.to_string(),
        format!("{:.1}", m.throughput()),
        format!("{:.2}", m.percentile_ms(0.50)),
        format!("{:.2}", m.percentile_ms(0.95)),
        format!("{:.2}", m.percentile_ms(0.99)),
        format!("{:.0}%", m.occupancy_mean() * 100.0),
        m.queue_depth_max().to_string(),
    ]);
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "e2e serving — continuous batcher, 48 requests per point",
        &[
            "engine",
            "offered req/s",
            "mode",
            "req/s",
            "p50 ms",
            "p95 ms",
            "p99 ms",
            "occupancy",
            "max depth",
        ],
    );

    // --- PJRT backend (skipped gracefully when unavailable) --------------
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        for rate in [20.0, 60.0, 200.0] {
            match run_demo_metrics(&dir, 48, rate, BatchPolicy::default()) {
                Ok(m) => metrics_row(&mut t, "pjrt(micro)", rate, "continuous", &m),
                Err(e) => {
                    println!("(pjrt rows skipped: {e:#})");
                    break;
                }
            }
        }
    } else {
        println!("(artifacts/ missing — pjrt rows skipped, sim rows follow)");
    }

    // --- Simulated backend (always runs, same serving code path) ---------
    for rate in [200.0, 1_000.0, 4_000.0] {
        let m = run_demo_metrics_sim(
            &MICRO,
            AccelConfig::paper(),
            1.0,
            48,
            rate,
            BatchPolicy::default(),
        )?;
        metrics_row(&mut t, "sim(micro)", rate, "continuous", &m);
    }
    println!("{t}");

    // --- ablation: continuous vs stop-the-world at equal max_wait --------
    let mut t = Table::new(
        "batching ablation — swin-t sim card (time_scale 0.05), 64 requests",
        &[
            "offered req/s",
            "mode",
            "req/s",
            "p50 ms",
            "p99 ms",
            "occupancy",
        ],
    );
    for rate in [100.0, 400.0, 1_200.0] {
        for mode in [BatchMode::Continuous, BatchMode::StopTheWorld] {
            let m = run_demo_metrics_sim(
                &TINY,
                AccelConfig::paper(),
                0.05,
                64,
                rate,
                BatchPolicy {
                    max_batch: 32,
                    max_wait: Duration::from_millis(6),
                    mode,
                    ..Default::default()
                },
            )?;
            t.row(&[
                format!("{rate:.0}"),
                match mode {
                    BatchMode::Continuous => "continuous".into(),
                    BatchMode::StopTheWorld => "stop-the-world".into(),
                },
                format!("{:.1}", m.throughput()),
                format!("{:.2}", m.percentile_ms(0.50)),
                format!("{:.2}", m.percentile_ms(0.99)),
                format!("{:.0}%", m.occupancy_mean() * 100.0),
            ]);
        }
    }
    println!("{t}");

    // --- fleet: the same Router over Vec<Box<dyn Engine>> ----------------
    let mut t = Table::new(
        "fleet routing over dyn Engine (virtual time, 400 requests)",
        &["cards", "offered FPS", "policy", "p50 ms", "p99 ms"],
    );
    for cards in [1usize, 2, 4] {
        for rate in [30.0, 80.0, 150.0] {
            for policy in [Policy::RoundRobin, Policy::LeastLoaded] {
                let engines: Vec<Box<dyn Engine>> = (0..cards)
                    .map(|i| {
                        Box::new(SimEngine::new(i, &TINY, AccelConfig::paper(), 0.0))
                            as Box<dyn Engine>
                    })
                    .collect();
                let mut r = Router::from_engines(engines, policy);
                let lats = r.run_poisson(400, rate, 11);
                t.row(&[
                    cards.to_string(),
                    format!("{rate:.0}"),
                    policy.name().into(),
                    format!("{:.1}", percentile(&lats, 0.50)),
                    format!("{:.1}", percentile(&lats, 0.99)),
                ]);
            }
        }
    }
    println!("{t}");
    Ok(())
}
