//! End-to-end serving bench: PJRT engines behind the router/batcher,
//! offered-load sweep + batching-policy ablation (DESIGN.md §6).
//! Requires `artifacts/`.

use std::path::PathBuf;

use swin_fpga::report::Table;
use swin_fpga::server::run_demo_metrics;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping");
        return Ok(());
    }

    let mut t = Table::new(
        "e2e serving (swin-micro, PJRT CPU, 48 requests per point)",
        &["offered req/s", "max batch", "throughput", "p50 ms", "p99 ms"],
    );
    for rate in [20.0, 60.0, 200.0] {
        for max_batch in [1usize, 8] {
            let m = run_demo_metrics(&dir, 48, rate, max_batch)?;
            t.row(&[
                format!("{rate:.0}"),
                max_batch.to_string(),
                format!("{:.1}", m.throughput()),
                format!("{:.2}", m.percentile_ms(0.50)),
                format!("{:.2}", m.percentile_ms(0.99)),
            ]);
        }
    }
    println!("{t}");
    Ok(())
}
