//! Bench/report for paper Fig. 11: relative speedup vs CPU and GPU
//! (modelled devices + live PJRT-CPU measurement when artifacts exist).

use std::path::PathBuf;

use swin_fpga::baseline::live;
use swin_fpga::report;

fn main() {
    println!("{}", report::fig11_speedup());

    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        match live::measure_live_cpu(&dir, 5) {
            Ok(s) => println!("{s}"),
            Err(e) => println!("(live CPU skipped: {e})"),
        }
    }
}
