//! Bench/report for paper Fig. 12: energy efficiency (FPS/W) of the
//! accelerator vs CPU/GPU, with the power model's breakdown — and the
//! per-launch J/inference columns the energy-aware router prices with
//! (busy-fraction-weighted dynamic + static over the launch span,
//! cold batch-1 vs warm batch-8 per image, per nonlinear-unit design).

use swin_fpga::accel::nonlinear::NlDesign;
use swin_fpga::accel::pipeline::{PipelineSchedule, Resource};
use swin_fpga::accel::power::{
    accelerator_power_w, launch_energy_j, Activity, SpanBusy, P_STATIC_W,
};
use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::AccelConfig;
use swin_fpga::report::{self, Table};

fn main() {
    println!("{}", report::fig12_energy());

    let cfg = AccelConfig::paper();
    let mut t = Table::new(
        "Power model detail",
        &["Model", "total W", "static W", "paper W"],
    );
    let paper = [10.69, 10.69, 11.11];
    for (v, pw) in report::paper_variants().iter().zip(paper) {
        let r = Simulator::new(v, cfg.clone()).simulate_inference();
        let p = accelerator_power_w(v, &cfg, &r, Activity::from_sim(&r));
        t.row(&[
            v.name.to_string(),
            format!("{p:.2}"),
            format!("{P_STATIC_W:.2}"),
            format!("{pw:.2}"),
        ]);
    }
    println!("{t}");

    // J/inference as the fleet router prices it: cold single-image
    // launch vs warm batch-8 steady state (per image) — the quantity the
    // Energy load signal trades against latency
    let mut t = Table::new(
        "J/inference (launch-span energy model)",
        &["Model", "design", "cold b1 J", "warm b8 J/img", "warm b8 W"],
    );
    for v in report::paper_variants() {
        for d in NlDesign::ALL {
            let dcfg = cfg.clone().nonlinear(d);
            let s = PipelineSchedule::for_variant(v, dcfg.clone());
            let busy = |b: usize| SpanBusy {
                mmu: s.busy_batched(Resource::Mmu, b),
                scu: s.busy_batched(Resource::Scu, b),
                gcu: s.busy_batched(Resource::Gcu, b),
                mru: s.busy_batched(Resource::Mru, b),
            };
            let cold1 = launch_energy_j(v, &dcfg, busy(1), s.launch_cycles(1));
            let warm8_span = s.steady_launch_cycles(8);
            let warm8 = launch_energy_j(v, &dcfg, busy(8), warm8_span);
            let warm8_w = warm8 / (warm8_span as f64 / (dcfg.freq_mhz * 1e6));
            t.row(&[
                v.name.to_string(),
                d.name().to_string(),
                format!("{cold1:.3}"),
                format!("{:.3}", warm8 / 8.0),
                format!("{warm8_w:.2}"),
            ]);
        }
    }
    println!("{t}");
}
