//! Bench/report for paper Fig. 12: energy efficiency (FPS/W) of the
//! accelerator vs CPU/GPU, with the power model's breakdown.

use swin_fpga::accel::power::{accelerator_power_w, Activity, P_STATIC_W};
use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::AccelConfig;
use swin_fpga::report::{self, Table};

fn main() {
    println!("{}", report::fig12_energy());

    let cfg = AccelConfig::paper();
    let mut t = Table::new(
        "Power model detail",
        &["Model", "total W", "static W", "paper W"],
    );
    let paper = [10.69, 10.69, 11.11];
    for (v, pw) in report::paper_variants().iter().zip(paper) {
        let r = Simulator::new(v, cfg.clone()).simulate_inference();
        let p = accelerator_power_w(v, &cfg, &r, Activity::from_sim(&r));
        t.row(&[
            v.name.to_string(),
            format!("{p:.2}"),
            format!("{P_STATIC_W:.2}"),
            format!("{pw:.2}"),
        ]);
    }
    println!("{t}");
}
