//! Billion-arrival fleet bench — the sharded-router acceptance
//! experiment (`BENCH_fleet1b.json` at the repo root).
//!
//! Drives 1B arrivals (200k under `SWIN_BENCH_SHORT=1`) over a 256-card
//! heterogeneous fleet (128×Swin-T + 128×Swin-S, 16 shards) through
//! [`ShardedRouter::run_generated`] — the streaming mode that folds
//! completions into mergeable [`FleetStats`] instead of materialising
//! them — at `threads ∈ {1, 2, 4, 8}` ({1, 2} in the short run), and
//! asserts the merged statistics **`==`-identical** for every thread
//! count *and* for the retained O(N)-scan-pick single-threaded oracle
//! (which PR 5 pinned to the pre-calendar path). A run that changed a
//! modelled number is a failed run; only wall clock may differ.
//!
//! Determinism comes from three mechanisms (see `server::router` docs):
//! epoch-snapshot shard assignment, counter-based per-shard arrival
//! substreams ([`ShardArrivalGen`]), and the deterministic k-way drain
//! merge — the thread count is execution detail only.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Instant;

use swin_fpga::accel::AccelConfig;
use swin_fpga::report::Table;
use swin_fpga::server::router::{
    fleet_capacity_fps, hetero_ts_fleet_scaled, hetero_ts_fleet_scaled_send, FleetPolicy,
    FleetStats, Policy, ShardSpec, ShardedRouter,
};
use swin_fpga::server::workload::{Arrival, ShardArrivalGen};
use swin_fpga::util::json::Json;

/// Counting allocator: the allocations-per-arrival proxy (same idiom as
/// the hotpath bench; thread-shared, so it counts fleet-wide allocs).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const SCALE: usize = 64; // 64 × (2×T + 2×S) = 256 cards
const SHARDS: usize = 16;
const SEED: u64 = 31;

struct ThreadRun {
    threads: usize,
    arrivals_per_sec: f64,
    wall_s: f64,
    allocs_per_arrival: f64,
    stats: FleetStats,
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn run_json(r: &ThreadRun) -> Json {
    obj(vec![
        ("threads", Json::Num(r.threads as f64)),
        ("arrivals_per_sec", Json::Num(r.arrivals_per_sec)),
        ("wall_s", Json::Num(r.wall_s)),
        ("allocs_per_arrival", Json::Num(r.allocs_per_arrival)),
        ("p50_ms", Json::Num(r.stats.quantile_ms(0.50))),
        ("p99_ms", Json::Num(r.stats.quantile_ms(0.99))),
        ("completions", Json::Num(r.stats.completions as f64)),
        ("shed", Json::Num(r.stats.shed as f64)),
    ])
}

fn main() {
    let short = std::env::var("SWIN_BENCH_SHORT").is_ok();
    let n: usize = if short { 200_000 } else { 1_000_000_000 };
    let epoch_ms = if short { 100.0 } else { 10_000.0 };
    let thread_counts: &[usize] = if short { &[1, 2] } else { &[1, 2, 4, 8] };
    let cfg = AccelConfig::paper();

    // offered load: 2× modelled fleet capacity, split over independent
    // per-shard bursty substreams (superposition = fleet offered load)
    let cap = fleet_capacity_fps(&hetero_ts_fleet_scaled(&cfg, SCALE));
    let kind = Arrival::Bursty {
        high: 2.0 * cap / SHARDS as f64,
        burst_s: 0.2,
        gap_s: 0.3,
    };
    let per_shard = n / SHARDS;
    let gens = || -> Vec<ShardArrivalGen> {
        (0..SHARDS as u64)
            .map(|s| ShardArrivalGen::new(kind, per_shard, 0.5, SEED, s))
            .collect()
    };
    let mk = || {
        ShardedRouter::with_fleet(
            hetero_ts_fleet_scaled_send(&cfg, SCALE),
            Policy::LeastLoaded,
            FleetPolicy::default(),
            ShardSpec::new(SHARDS, epoch_ms),
        )
    };

    let mut router = mk();
    let mut runs: Vec<ThreadRun> = Vec::new();
    for &threads in thread_counts {
        let a0 = ALLOCS.load(Relaxed);
        let t0 = Instant::now();
        let stats = router.run_generated(gens(), threads);
        let wall = t0.elapsed().as_secs_f64();
        let allocs = ALLOCS.load(Relaxed) - a0;
        assert_eq!(stats.arrivals, (per_shard * SHARDS) as u64, "arrivals lost");
        runs.push(ThreadRun {
            threads,
            arrivals_per_sec: stats.arrivals as f64 / wall,
            wall_s: wall,
            allocs_per_arrival: allocs as f64 / stats.arrivals as f64,
            stats,
        });
    }

    // the acceptance identity: every thread count produced the same
    // statistics — completions, shed, checksum, full latency histogram
    for r in &runs[1..] {
        assert_eq!(
            r.stats, runs[0].stats,
            "threads={} diverged from threads={}",
            r.threads, runs[0].threads
        );
    }
    // ... and so does the retained O(N)-scan-pick oracle, run
    // single-threaded (itself pinned to the pre-calendar path by PR 5)
    let oracle = mk().with_scan_pick().run_generated(gens(), 1);
    assert_eq!(oracle, runs[0].stats, "scan-pick oracle diverged");

    let speedup = runs.last().unwrap().arrivals_per_sec / runs[0].arrivals_per_sec;

    let mut t = Table::new(
        &format!(
            "sharded fleet — 256-card 128×T+128×S, {SHARDS} shards, {n} bursty arrivals"
        ),
        &["threads", "arrivals/s", "wall s", "allocs/arrival", "p50 ms", "p99 ms"],
    );
    for r in &runs {
        t.row(&[
            format!("{}", r.threads),
            format!("{:.0}", r.arrivals_per_sec),
            format!("{:.2}", r.wall_s),
            format!("{:.3}", r.allocs_per_arrival),
            format!("{:.2}", r.stats.quantile_ms(0.50)),
            format!("{:.2}", r.stats.quantile_ms(0.99)),
        ]);
    }
    println!("{t}");
    println!(
        "speedup {speedup:.2}x arrivals/s at {} threads; stats identical across all \
         thread counts and the scan oracle (completions {}, shed {}, checksum {:#x})",
        runs.last().unwrap().threads,
        runs[0].stats.completions,
        runs[0].stats.shed,
        runs[0].stats.checksum,
    );

    let json = obj(vec![
        ("bench", Json::Str("fleet1b".into())),
        // schema note: one row per thread count; `threads` is the
        // execution width, everything modelled is asserted identical
        // across rows. `provenance` distinguishes native runs from
        // python-mirror estimates — the first toolchain'd run
        // overwrites any mirror numbers.
        (
            "provenance",
            Json::Str("native (cargo bench --bench fleet1b)".into()),
        ),
        (
            "workload",
            obj(vec![
                ("cards", Json::Num((4 * SCALE) as f64)),
                ("fleet", Json::Str("128x swin-t + 128x swin-s".into())),
                ("shards", Json::Num(SHARDS as f64)),
                ("epoch_ms", Json::Num(epoch_ms)),
                ("arrivals", Json::Num(n as f64)),
                (
                    "arrival_process",
                    Json::Str("bursty 2x capacity, per-shard substreams".into()),
                ),
                ("interactive_share", Json::Num(0.5)),
                ("seed", Json::Num(SEED as f64)),
            ]),
        ),
        ("runs", Json::Arr(runs.iter().map(run_json).collect())),
        (
            "speedup_arrivals_per_sec",
            obj(vec![(
                &format!("t{}_over_t1", runs.last().unwrap().threads),
                Json::Num(speedup),
            )]),
        ),
        ("deterministic_across_threads", Json::Bool(true)),
        ("matches_scan_oracle", Json::Bool(true)),
    ]);
    let path = "BENCH_fleet1b.json";
    std::fs::write(path, format!("{json}\n")).expect("write BENCH_fleet1b.json");
    println!("wrote {path}");
}
