//! Flash-crowd + one-card-crash fault experiment — the elastic-fleet
//! acceptance bench (`BENCH_faults.json` at the repo root).
//!
//! A 9-card fleet (2×(2×Swin-T + 2×Swin-S) live + one Swin-T spare that
//! starts **down**) takes a bursty flash crowd at 2.5× the live fleet's
//! modelled capacity. Three scenarios over the *same* arrivals:
//!
//! * **fault-free** — the spare stays parked (its join is scheduled past
//!   the horizon); 8 live cards ride out the crowd. The baseline.
//! * **elastic** — card 0 fail-stop-crashes mid-burst (the crash instant
//!   is read off the baseline run: the midpoint of an in-flight
//!   interactive launch on card 0, so the retraction provably strands
//!   interactive work); 50 ms later the spare joins. Retry budget 3:
//!   retracted requests redispatch to survivors with their original
//!   enqueue ticks.
//! * **static no-retry** — same crash, no join, retry budget 0: every
//!   retracted request is lost, and the fleet stays a card short.
//!
//! Asserted, not just reported:
//! * an installed **zero-fault plan is inert** (bit-identical to no
//!   plan) — the fault layer's identity contract, live in every run;
//! * the elastic scenario is **thread-count invariant** — completions
//!   and fault counters identical for every `threads`;
//! * elastic interactive p99 ≤ **1.5×** the fault-free interactive p99;
//! * the static fleet loses/sheds **strictly more** interactive
//!   requests than the elastic fleet.
//!
//! `SWIN_BENCH_SHORT=1` runs the CI-sized workload (same assertions).

use std::collections::BTreeMap;
use std::time::Instant;

use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::TINY;
use swin_fpga::report::Table;
use swin_fpga::server::fault::ms_to_cycles;
use swin_fpga::server::router::{
    fleet_capacity_fps, fleet_percentiles, hetero_ts_fleet_scaled, FaultCounters,
    FleetCompletion, FleetPolicy, Policy, ShardSpec, ShardedRouter,
};
use swin_fpga::server::workload::{classed_arrivals, Arrival, ClassedArrival};
use swin_fpga::server::{Engine, FaultEvent, FaultPlan, SimEngine, Slo};
use swin_fpga::util::json::Json;

const LIVE: usize = 8; // 2 × (2×Swin-T + 2×Swin-S)
const CARDS: usize = LIVE + 1; // + one Swin-T spare (join target)
const SHARDS: usize = 3;
const SEED: u64 = 31;
const JOIN_DELAY_MS: f64 = 50.0;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

struct Scenario {
    name: &'static str,
    comps: Vec<FleetCompletion>,
    counters: FaultCounters,
    health: [u64; 4],
    shed: u64,
    wall_s: f64,
}

fn interactive_served(comps: &[FleetCompletion]) -> u64 {
    comps.iter().filter(|c| c.class == Slo::Interactive).count() as u64
}

fn main() {
    let short = std::env::var("SWIN_BENCH_SHORT").is_ok();
    let n: usize = if short { 2_000 } else { 20_000 };
    let thread_counts: &[usize] = if short { &[1, 2] } else { &[1, 2, 4] };
    let cfg = AccelConfig::paper();

    let mk = || {
        let mut engines = swin_fpga::server::router::hetero_ts_fleet_scaled_send(&cfg, 2);
        engines.push(Box::new(SimEngine::new(LIVE, &TINY, cfg.clone(), 0.0))
            as Box<dyn Engine + Send>);
        assert_eq!(engines.len(), CARDS);
        ShardedRouter::with_fleet(
            engines,
            Policy::LeastLoaded,
            FleetPolicy::default(),
            ShardSpec::new(SHARDS, 10.0),
        )
    };

    // flash crowd: 2.5× the LIVE fleet's capacity (the spare is down)
    let cap = fleet_capacity_fps(&hetero_ts_fleet_scaled(&cfg, 2));
    let arr: Vec<ClassedArrival> = classed_arrivals(
        Arrival::Bursty { high: 2.5 * cap, burst_s: 0.25, gap_s: 0.35 },
        n,
        0.5,
        SEED,
    );
    let submitted_inter = arr.iter().filter(|a| a.class == Slo::Interactive).count() as u64;

    // the spare starts down: its join is parked past the horizon (it
    // fires during the final drain, after the last completion)
    let park = |budget: u32| -> FaultPlan {
        let mut p = FaultPlan::none(CARDS);
        p.retry_budget = budget;
        p.push(LIVE, FaultEvent::Join { at: u64::MAX });
        p
    };

    // zero-fault identity, asserted live: an installed-but-empty plan
    // must not perturb a single cycle
    {
        let mut plain = mk();
        let a = plain.run_classed(&arr, 1);
        let mut zero = mk().with_faults(FaultPlan::none(CARDS));
        let b = zero.run_classed(&arr, 1);
        assert_eq!(a, b, "zero-fault plan perturbed the run");
        assert_eq!(zero.fault_counters(), FaultCounters::default());
    }

    let run = |name: &'static str, plan: FaultPlan, threads: usize| -> Scenario {
        let mut s = mk().with_faults(plan);
        let t0 = Instant::now();
        let comps = s.run_classed(&arr, threads);
        Scenario {
            name,
            counters: s.fault_counters(),
            health: s.health_counts(),
            shed: s.shed_count(),
            wall_s: t0.elapsed().as_secs_f64(),
            comps,
        }
    };

    // ---- baseline: 8 live cards, no faults inside the horizon
    let base = run("fault-free", park(3), 1);
    assert_eq!(base.counters.crash_lost, 0);

    // crash instant: midpoint of an in-flight *interactive* launch on
    // card 0 near the first quarter of the baseline run — the retracted
    // set then provably contains interactive work. Pre-crash dynamics
    // are identical across scenarios (the plans only diverge at the
    // crash), so the window read off the baseline is valid in all.
    let horizon = base.comps.iter().map(|c| c.finish).max().unwrap_or(0);
    let target = horizon / 4;
    let window = base
        .comps
        .iter()
        .filter(|c| c.device == 0 && c.class == Slo::Interactive && c.finish > c.start)
        .min_by_key(|c| c.start.abs_diff(target))
        .expect("card 0 served interactive work in the baseline");
    let crash_at = window.start + (window.finish - window.start) / 2;
    let join_at = crash_at + ms_to_cycles(JOIN_DELAY_MS);

    let elastic_plan = {
        let mut p = FaultPlan::none(CARDS);
        p.retry_budget = 3;
        p.push(0, FaultEvent::Crash { at: crash_at });
        p.push(LIVE, FaultEvent::Join { at: join_at });
        p
    };
    let static_plan = {
        let mut p = park(0);
        p.push(0, FaultEvent::Crash { at: crash_at });
        p
    };

    // ---- elastic: crash + spare join + retries, at every thread count
    let elastic = run("elastic", elastic_plan.clone(), thread_counts[0]);
    let mut elastic_walls: Vec<(usize, f64)> = vec![(thread_counts[0], elastic.wall_s)];
    for &k in &thread_counts[1..] {
        let again = run("elastic", elastic_plan.clone(), k);
        assert_eq!(again.comps, elastic.comps, "threads={k} diverged");
        assert_eq!(again.counters, elastic.counters, "threads={k} counters");
        assert_eq!(again.health, elastic.health, "threads={k} health");
        elastic_walls.push((k, again.wall_s));
    }
    assert!(elastic.counters.crash_lost > 0, "crash retracted nothing");
    assert!(elastic.counters.redispatched > 0, "nothing redispatched");
    assert_eq!(elastic.health, [8, 0, 0, 1], "crashed card down, spare up");

    // ---- static: same crash, no join, no retries
    let stat = run("static no-retry", static_plan, thread_counts[0]);
    assert!(stat.counters.lost > 0, "budget 0 must lose the retracted work");

    // ---- the headline comparisons
    let p_base = fleet_percentiles(&base.comps);
    let p_el = fleet_percentiles(&elastic.comps);
    let p_st = fleet_percentiles(&stat.comps);
    let miss = |s: &Scenario| submitted_inter - interactive_served(&s.comps);
    let (m_base, m_el, m_st) = (miss(&base), miss(&elastic), miss(&stat));
    assert!(
        p_el[2] <= 1.5 * p_base[2],
        "elastic interactive p99 {:.1} ms blew past 1.5x fault-free {:.1} ms",
        p_el[2],
        p_base[2]
    );
    assert!(
        m_st > m_el,
        "static fleet must lose/shed strictly more interactive: static {m_st} vs elastic {m_el}"
    );

    let mut t = Table::new(
        &format!(
            "faulted fleet — {CARDS} cards ({LIVE} live + 1 spare), {SHARDS} shards, \
             {n} bursty arrivals at 2.5x capacity, crash at {:.0} ms / join +{JOIN_DELAY_MS} ms",
            crash_at as f64 / 200_000.0
        ),
        &[
            "scenario", "p50 ms", "p99 ms", "inter p99", "batch p99", "served", "shed",
            "lost", "inter missing",
        ],
    );
    for (s, p, m) in [(&base, p_base, m_base), (&elastic, p_el, m_el), (&stat, p_st, m_st)] {
        t.row(&[
            s.name.to_string(),
            format!("{:.1}", p[0]),
            format!("{:.1}", p[1]),
            format!("{:.1}", p[2]),
            format!("{:.1}", p[3]),
            format!("{}", s.comps.len()),
            format!("{}", s.shed),
            format!("{}", s.counters.lost),
            format!("{m}"),
        ]);
    }
    println!("{t}");
    println!(
        "elastic: {} retries, {} redispatched, {} crash-lost, {} lost; interactive p99 \
         {:.1} ms vs fault-free {:.1} ms ({:.2}x, bound 1.5x); static missing {} vs \
         elastic {} interactive",
        elastic.counters.retries,
        elastic.counters.redispatched,
        elastic.counters.crash_lost,
        elastic.counters.lost,
        p_el[2],
        p_base[2],
        p_el[2] / p_base[2].max(1e-9),
        m_st,
        m_el,
    );

    let scen_json = |s: &Scenario, p: [f64; 4], m: u64| -> Json {
        obj(vec![
            ("name", Json::Str(s.name.into())),
            ("p50_ms", Json::Num(p[0])),
            ("p99_ms", Json::Num(p[1])),
            ("interactive_p99_ms", Json::Num(p[2])),
            ("batch_p99_ms", Json::Num(p[3])),
            ("served", Json::Num(s.comps.len() as f64)),
            ("shed", Json::Num(s.shed as f64)),
            ("retries", Json::Num(s.counters.retries as f64)),
            ("redispatched", Json::Num(s.counters.redispatched as f64)),
            ("crash_lost", Json::Num(s.counters.crash_lost as f64)),
            ("lost", Json::Num(s.counters.lost as f64)),
            ("interactive_missing", Json::Num(m as f64)),
            (
                "health_final",
                Json::Arr(s.health.iter().map(|&h| Json::Num(h as f64)).collect()),
            ),
            ("wall_s", Json::Num(s.wall_s)),
        ])
    };
    let json = obj(vec![
        ("bench", Json::Str("fleet_faults".into())),
        (
            "provenance",
            Json::Str("native (cargo bench --bench fleet_faults)".into()),
        ),
        (
            "workload",
            obj(vec![
                ("cards", Json::Num(CARDS as f64)),
                (
                    "fleet",
                    Json::Str("2x(2xswin-t + 2xswin-s) live + 1 swin-t spare (down)".into()),
                ),
                ("shards", Json::Num(SHARDS as f64)),
                ("arrivals", Json::Num(n as f64)),
                (
                    "arrival_process",
                    Json::Str("bursty flash crowd, 2.5x live capacity".into()),
                ),
                ("interactive_share", Json::Num(0.5)),
                ("seed", Json::Num(SEED as f64)),
                ("crash_at_ms", Json::Num(crash_at as f64 / 200_000.0)),
                ("join_delay_ms", Json::Num(JOIN_DELAY_MS)),
            ]),
        ),
        (
            "scenarios",
            Json::Arr(vec![
                scen_json(&base, p_base, m_base),
                scen_json(&elastic, p_el, m_el),
                scen_json(&stat, p_st, m_st),
            ]),
        ),
        (
            "elastic_walls_s",
            Json::Arr(
                elastic_walls
                    .iter()
                    .map(|&(k, w)| {
                        obj(vec![
                            ("threads", Json::Num(k as f64)),
                            ("wall_s", Json::Num(w)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "elastic_inter_p99_over_fault_free",
            Json::Num(p_el[2] / p_base[2].max(1e-9)),
        ),
        ("zero_fault_identity", Json::Bool(true)),
        ("deterministic_across_threads", Json::Bool(true)),
    ]);
    let path = "BENCH_faults.json";
    std::fs::write(path, format!("{json}\n")).expect("write BENCH_faults.json");
    println!("wrote {path}");
}
