//! Fleet scaling bench: multi-card routing over simulated accelerators in
//! virtual time (the paper's edge-deployment scenario scaled out).
//! Reports p50/p99 latency vs offered load, card count and policy.

use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::TINY;
use swin_fpga::report::Table;
use swin_fpga::server::router::{percentile, Policy, Router};
use swin_fpga::util::bench::{bench_default, black_box};

fn main() {
    let mut t = Table::new(
        "fleet scaling — swin-t cards, Poisson arrivals, 600 requests",
        &["cards", "offered FPS", "policy", "p50 ms", "p99 ms", "per-card FPS"],
    );
    for cards in [1usize, 2, 4, 8] {
        for rate in [30.0, 80.0, 150.0] {
            for policy in [Policy::RoundRobin, Policy::LeastLoaded] {
                let mut r = Router::new(cards, &TINY, AccelConfig::paper(), policy);
                let lats = r.run_poisson(600, rate, 11);
                let served_share = r.total_served() as f64 / cards as f64;
                t.row(&[
                    cards.to_string(),
                    format!("{rate:.0}"),
                    policy.name().into(),
                    format!("{:.1}", percentile(&lats, 0.50)),
                    format!("{:.1}", percentile(&lats, 0.99)),
                    format!("{:.0}", served_share),
                ]);
            }
        }
    }
    println!("{t}");

    // routing overhead itself (L3 hot path)
    let mut r = Router::new(8, &TINY, AccelConfig::paper(), Policy::LeastLoaded);
    println!(
        "{}",
        bench_default("route() 8-card least-loaded", || {
            black_box(r.route(0));
        })
    );
}
