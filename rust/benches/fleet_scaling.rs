//! Fleet scaling bench: multi-card routing over simulated accelerators in
//! virtual time (the paper's edge-deployment scenario scaled out).
//! Reports p50/p99 latency vs offered load, card count and policy — for
//! both the legacy whole-request dispatch and the per-card batcher
//! queues (backlog-aware vs busy-horizon load signals, warm vs cold
//! launch timing — the cross-launch-prefetch ablation).
//!
//! The latency-vs-energy Pareto sweep (PR 9) runs the heterogeneous
//! 2×T + 2×S fleet under bursty load at 0.7× modelled capacity, prices
//! marginal J/inference into the routing signal across a weight sweep
//! (idle gating off/on), asserts the zero-weight/ungated run reproduces
//! `Backlog` bit-for-bit, asserts an energy-routed row strictly cuts
//! J/inference at (near-)equal interactive p99, and dumps the table to
//! `PARETO_energy.json` (model-derived numbers, not board measurements).
//!
//! Set `SWIN_BENCH_SHORT=1` for the CI smoke run (fewer requests).

use std::collections::BTreeMap;

use swin_fpga::accel::shard::ShardCostTable;
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{BASE_384, LARGE_384, TINY};
use swin_fpga::report::Table;
use swin_fpga::server::router::{
    fleet_capacity_fps, fleet_percentiles, hetero_ts_fleet, percentile, LoadModel, Policy,
    Router,
};
use swin_fpga::server::workload::{bursty_at_fraction, classed_arrivals, Arrival};
use swin_fpga::server::ShardedEngine;
use swin_fpga::util::bench::{bench_default, black_box};
use swin_fpga::util::json::Json;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn main() {
    let short = std::env::var("SWIN_BENCH_SHORT").is_ok();
    let n = if short { 150 } else { 600 };

    let title = format!("fleet scaling — swin-t cards, Poisson arrivals, {n} requests");
    let mut t = Table::new(
        &title,
        &["cards", "offered FPS", "policy", "p50 ms", "p99 ms", "per-card FPS"],
    );
    for cards in [1usize, 2, 4, 8] {
        for rate in [30.0, 80.0, 150.0] {
            for policy in [Policy::RoundRobin, Policy::LeastLoaded] {
                let mut r = Router::new(cards, &TINY, AccelConfig::paper(), policy);
                let lats = r.run_poisson(n, rate, 11);
                let served_share = r.total_served() as f64 / cards as f64;
                t.row(&[
                    cards.to_string(),
                    format!("{rate:.0}"),
                    policy.name().into(),
                    format!("{:.1}", percentile(&lats, 0.50)),
                    format!("{:.1}", percentile(&lats, 0.99)),
                    format!("{:.0}", served_share),
                ]);
            }
        }
    }
    println!("{t}");

    // queued fleet: per-card continuous batchers, load-signal ablation ×
    // warm/cold launch timing (cross-launch prefetch on/off)
    let title = format!(
        "queued fleet — swin-t cards, per-card batchers, bursty arrivals, {n} requests"
    );
    let mut t = Table::new(
        &title,
        &["cards", "load signal", "timing", "p50 ms", "p99 ms", "per-card served"],
    );
    let timings = [
        ("cold", AccelConfig::paper().interlaunch(false)),
        ("warm", AccelConfig::paper()),
    ];
    for cards in [2usize, 4, 8] {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 60.0 * cards as f64, burst_s: 0.2, gap_s: 0.3 },
            n,
            0.5,
            11,
        );
        for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
            for (label, cfg) in &timings {
                let mut r = Router::new(cards, &TINY, cfg.clone(), Policy::LeastLoaded)
                    .with_load(load);
                let comps = r.run_classed(&arr);
                let [p50, p99, ..] = fleet_percentiles(&comps);
                t.row(&[
                    cards.to_string(),
                    load.name().into(),
                    (*label).into(),
                    format!("{p50:.1}"),
                    format!("{p99:.1}"),
                    format!("{:.0}", r.total_served() as f64 / cards as f64),
                ]);
            }
        }
    }
    println!("{t}");

    // latency-vs-energy Pareto — the PR-9 acceptance experiment: the
    // heterogeneous 2×T + 2×S fleet under bursty arrivals at 0.7× its
    // modelled capacity. Backlog (latency-only) is the baseline; Energy
    // rows sweep the cycles-per-mJ weight with idle gating off/on.
    let n_pareto = if short { 250 } else { 1_000 };
    let cfg = AccelConfig::paper();
    let cap = fleet_capacity_fps(&hetero_ts_fleet(&cfg));
    let arr = bursty_at_fraction(0.7, cap, n_pareto, 0.5, 13);
    let run = |load: LoadModel, weight: u64, gated: bool| {
        let mut r = Router::from_engines(hetero_ts_fleet(&cfg), Policy::LeastLoaded)
            .with_load(load)
            .with_energy_weight(weight)
            .with_idle_gating(gated);
        let comps = r.run_classed(&arr);
        let horizon = comps.iter().map(|c| c.finish).max().unwrap_or(0);
        let uj = r.fleet_energy_uj(horizon);
        let spent = r.energy_spent_uj();
        let shed = r.shed_count();
        (comps, uj, spent, shed)
    };

    struct ParetoRow {
        label: String,
        weight: u64,
        gated: bool,
        p50: f64,
        p99: f64,
        inter_p99: f64,
        batch_p99: f64,
        j_per_inf: f64,
        completions: usize,
        shed: u64,
    }
    let weights: &[u64] = if short {
        &[0, 3_000, 30_000]
    } else {
        &[0, 1_000, 3_000, 10_000, 30_000]
    };
    let mut configs: Vec<(String, LoadModel, u64, bool)> =
        vec![("backlog".to_string(), LoadModel::Backlog, 0, false)];
    for &w in weights {
        for gated in [false, true] {
            configs.push((
                format!("energy w={w}{}", if gated { " gated" } else { "" }),
                LoadModel::Energy,
                w,
                gated,
            ));
        }
    }
    let mut rows: Vec<ParetoRow> = Vec::new();
    let mut base_stream = None; // (completions, energy_spent_uj) of Backlog
    let mut zero_stream = None; // ... of Energy at weight 0, gating off
    for (label, load, weight, gated) in configs {
        let (comps, uj, spent, shed) = run(load, weight, gated);
        let [p50, p99, inter_p99, batch_p99] = fleet_percentiles(&comps);
        rows.push(ParetoRow {
            label,
            weight,
            gated,
            p50,
            p99,
            inter_p99,
            batch_p99,
            j_per_inf: uj as f64 / 1e6 / comps.len().max(1) as f64,
            completions: comps.len(),
            shed,
        });
        if load == LoadModel::Backlog {
            base_stream = Some((comps, spent));
        } else if weight == 0 && !gated {
            zero_stream = Some((comps, spent));
        }
    }

    // the differential oracle: zero energy weight with gating off must
    // reproduce the latency-only Backlog routing bit-for-bit — same
    // completion stream, same booked launch energy
    let (base_comps, base_spent) = base_stream.expect("backlog row ran");
    let (zero_comps, zero_spent) = zero_stream.expect("zero-weight row ran");
    assert!(
        base_comps.len() == zero_comps.len()
            && base_comps.iter().zip(&zero_comps).all(|(a, b)| {
                (a.idx, a.device, a.arrival, a.start, a.finish)
                    == (b.idx, b.device, b.arrival, b.start, b.finish)
            }),
        "Energy at zero weight diverged from Backlog"
    );
    assert_eq!(base_spent, zero_spent, "zero-weight run booked different energy");

    let mut t = Table::new(
        &format!(
            "latency vs energy Pareto — 2xT + 2xS fleet, bursty @ 0.7x capacity \
             ({cap:.0} fps), {n_pareto} requests"
        ),
        &["routing", "p50 ms", "p99 ms", "interactive p99", "batch p99", "J/inf", "shed"],
    );
    for r in &rows {
        t.row(&[
            r.label.clone(),
            format!("{:.1}", r.p50),
            format!("{:.1}", r.p99),
            format!("{:.1}", r.inter_p99),
            format!("{:.1}", r.batch_p99),
            format!("{:.2}", r.j_per_inf),
            format!("{}", r.shed),
        ]);
    }
    println!("{t}");

    // the acceptance claim: some energy-routed row strictly cuts fleet
    // J/inference while holding interactive p99 at (near-)equal level
    let base = &rows[0];
    let inter_tol = (base.inter_p99 * 1.05).max(base.inter_p99 + 2.0);
    let winner = rows[1..]
        .iter()
        .filter(|r| r.j_per_inf < base.j_per_inf && r.inter_p99 <= inter_tol)
        .min_by(|a, b| a.j_per_inf.partial_cmp(&b.j_per_inf).unwrap())
        .expect("no energy row cut J/inference at (near-)equal interactive p99");
    println!(
        "pareto: `{}` cuts fleet energy {:.2} -> {:.2} J/inference \
         (interactive p99 {:.1} ms vs backlog {:.1} ms)\n",
        winner.label, base.j_per_inf, winner.j_per_inf, winner.inter_p99, base.inter_p99,
    );

    let row_json = |r: &ParetoRow| {
        obj(vec![
            ("routing", Json::Str(r.label.clone())),
            ("energy_weight_cycles_per_mj", Json::Num(r.weight as f64)),
            ("idle_gated", Json::Bool(r.gated)),
            ("p50_ms", Json::Num(r.p50)),
            ("p99_ms", Json::Num(r.p99)),
            ("interactive_p99_ms", Json::Num(r.inter_p99)),
            ("batch_p99_ms", Json::Num(r.batch_p99)),
            ("j_per_inference", Json::Num(r.j_per_inf)),
            ("completions", Json::Num(r.completions as f64)),
            ("shed", Json::Num(r.shed as f64)),
        ])
    };
    let json = obj(vec![
        ("bench", Json::Str("fleet_scaling:pareto_energy".into())),
        // `provenance` marks these as cycle/power-model numbers from the
        // simulated fleet, not board measurements
        (
            "provenance",
            Json::Str(
                "model-derived (cargo bench --bench fleet_scaling); simulated \
                 cycle + power model, not board measurements"
                    .into(),
            ),
        ),
        (
            "workload",
            obj(vec![
                ("fleet", Json::Str("2x swin-t + 2x swin-s".into())),
                ("capacity_fps", Json::Num(cap)),
                ("offered_fraction", Json::Num(0.7)),
                ("requests", Json::Num(n_pareto as f64)),
                ("interactive_share", Json::Num(0.5)),
                ("seed", Json::Num(13.0)),
            ]),
        ),
        ("rows", Json::Arr(rows.iter().map(row_json).collect())),
        ("zero_weight_matches_backlog", Json::Bool(true)),
        (
            "pareto_win",
            obj(vec![
                ("routing", Json::Str(winner.label.clone())),
                ("j_per_inference", Json::Num(winner.j_per_inf)),
                ("baseline_j_per_inference", Json::Num(base.j_per_inf)),
                ("interactive_p99_ms", Json::Num(winner.inter_p99)),
                ("baseline_interactive_p99_ms", Json::Num(base.inter_p99)),
            ]),
        ),
    ]);
    let path = "PARETO_energy.json";
    std::fs::write(path, format!("{json}\n")).expect("write PARETO_energy.json");
    println!("wrote {path}");

    // sharded pipelines: the 384-input variants that overflow one card,
    // served across a pipeline-parallel card group (cold = end-to-end
    // pipeline latency, warm = slowest shard's steady rate)
    let mut t = Table::new(
        "sharded pipelines — 384-input variants across XCZU19EG cards",
        &["variant", "cards", "batch", "cold ms", "warm ms", "steady FPS", "FPS/card"],
    );
    for v in [&BASE_384, &LARGE_384] {
        let table = ShardCostTable::for_variant(v, AccelConfig::paper(), &[1, 8]);
        let cards = table.schedule().cards();
        for b in [1usize, 8] {
            let fps = b as f64 * 1e3 / table.warm_ms(b);
            t.row(&[
                v.name.into(),
                cards.to_string(),
                b.to_string(),
                format!("{:.2}", table.cold_ms(b)),
                format!("{:.2}", table.warm_ms(b)),
                format!("{fps:.1}"),
                format!("{:.1}", fps / cards as f64),
            ]);
        }
    }
    println!("{t}");

    // a sharded Swin-L/384 group behind the shared router, next to the
    // canonical T/S fleet — the pipeline group is just another engine
    let n_shard = if short { 120 } else { 400 };
    let cfg = AccelConfig::paper();
    let mut engines = hetero_ts_fleet(&cfg);
    let id = engines.len();
    engines.push(Box::new(ShardedEngine::new(id, &LARGE_384, cfg, 0.0)));
    let names: Vec<String> = engines.iter().map(|e| e.name()).collect();
    let mut r = Router::from_engines(engines, Policy::LeastLoaded);
    let arr = classed_arrivals(Arrival::Poisson { rate: 90.0 }, n_shard, 0.5, 17);
    let comps = r.run_classed(&arr);
    let [p50, p99, ..] = fleet_percentiles(&comps);
    println!(
        "mixed fleet + sharded swin-l-384 group: {n_shard} requests, p50 {p50:.1} ms, p99 {p99:.1} ms, shed {}",
        r.shed_count()
    );
    for (name, served) in names.iter().zip(r.served()) {
        println!("  {name}: {served} served");
    }
    println!();

    // routing overhead itself (L3 hot path)
    let mut r = Router::new(8, &TINY, AccelConfig::paper(), Policy::LeastLoaded);
    println!(
        "{}",
        bench_default("route() 8-card least-loaded", || {
            black_box(r.route(0));
        })
    );
}
