//! Fleet scaling bench: multi-card routing over simulated accelerators in
//! virtual time (the paper's edge-deployment scenario scaled out).
//! Reports p50/p99 latency vs offered load, card count and policy — for
//! both the legacy whole-request dispatch and the per-card batcher
//! queues (backlog-aware vs busy-horizon load signals, warm vs cold
//! launch timing — the cross-launch-prefetch ablation).
//!
//! Set `SWIN_BENCH_SHORT=1` for the CI smoke run (fewer requests).

use swin_fpga::accel::shard::ShardCostTable;
use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{BASE_384, LARGE_384, TINY};
use swin_fpga::report::Table;
use swin_fpga::server::router::{
    fleet_percentiles, hetero_ts_fleet, percentile, LoadModel, Policy, Router,
};
use swin_fpga::server::workload::{classed_arrivals, Arrival};
use swin_fpga::server::ShardedEngine;
use swin_fpga::util::bench::{bench_default, black_box};

fn main() {
    let short = std::env::var("SWIN_BENCH_SHORT").is_ok();
    let n = if short { 150 } else { 600 };

    let title = format!("fleet scaling — swin-t cards, Poisson arrivals, {n} requests");
    let mut t = Table::new(
        &title,
        &["cards", "offered FPS", "policy", "p50 ms", "p99 ms", "per-card FPS"],
    );
    for cards in [1usize, 2, 4, 8] {
        for rate in [30.0, 80.0, 150.0] {
            for policy in [Policy::RoundRobin, Policy::LeastLoaded] {
                let mut r = Router::new(cards, &TINY, AccelConfig::paper(), policy);
                let lats = r.run_poisson(n, rate, 11);
                let served_share = r.total_served() as f64 / cards as f64;
                t.row(&[
                    cards.to_string(),
                    format!("{rate:.0}"),
                    policy.name().into(),
                    format!("{:.1}", percentile(&lats, 0.50)),
                    format!("{:.1}", percentile(&lats, 0.99)),
                    format!("{:.0}", served_share),
                ]);
            }
        }
    }
    println!("{t}");

    // queued fleet: per-card continuous batchers, load-signal ablation ×
    // warm/cold launch timing (cross-launch prefetch on/off)
    let title = format!(
        "queued fleet — swin-t cards, per-card batchers, bursty arrivals, {n} requests"
    );
    let mut t = Table::new(
        &title,
        &["cards", "load signal", "timing", "p50 ms", "p99 ms", "per-card served"],
    );
    let timings = [
        ("cold", AccelConfig::paper().interlaunch(false)),
        ("warm", AccelConfig::paper()),
    ];
    for cards in [2usize, 4, 8] {
        let arr = classed_arrivals(
            Arrival::Bursty { high: 60.0 * cards as f64, burst_s: 0.2, gap_s: 0.3 },
            n,
            0.5,
            11,
        );
        for load in [LoadModel::BusyHorizon, LoadModel::Backlog] {
            for (label, cfg) in &timings {
                let mut r = Router::new(cards, &TINY, cfg.clone(), Policy::LeastLoaded)
                    .with_load(load);
                let comps = r.run_classed(&arr);
                let [p50, p99, ..] = fleet_percentiles(&comps);
                t.row(&[
                    cards.to_string(),
                    load.name().into(),
                    (*label).into(),
                    format!("{p50:.1}"),
                    format!("{p99:.1}"),
                    format!("{:.0}", r.total_served() as f64 / cards as f64),
                ]);
            }
        }
    }
    println!("{t}");

    // sharded pipelines: the 384-input variants that overflow one card,
    // served across a pipeline-parallel card group (cold = end-to-end
    // pipeline latency, warm = slowest shard's steady rate)
    let mut t = Table::new(
        "sharded pipelines — 384-input variants across XCZU19EG cards",
        &["variant", "cards", "batch", "cold ms", "warm ms", "steady FPS", "FPS/card"],
    );
    for v in [&BASE_384, &LARGE_384] {
        let table = ShardCostTable::for_variant(v, AccelConfig::paper(), &[1, 8]);
        let cards = table.schedule().cards();
        for b in [1usize, 8] {
            let fps = b as f64 * 1e3 / table.warm_ms(b);
            t.row(&[
                v.name.into(),
                cards.to_string(),
                b.to_string(),
                format!("{:.2}", table.cold_ms(b)),
                format!("{:.2}", table.warm_ms(b)),
                format!("{fps:.1}"),
                format!("{:.1}", fps / cards as f64),
            ]);
        }
    }
    println!("{t}");

    // a sharded Swin-L/384 group behind the shared router, next to the
    // canonical T/S fleet — the pipeline group is just another engine
    let n_shard = if short { 120 } else { 400 };
    let cfg = AccelConfig::paper();
    let mut engines = hetero_ts_fleet(&cfg);
    let id = engines.len();
    engines.push(Box::new(ShardedEngine::new(id, &LARGE_384, cfg, 0.0)));
    let names: Vec<String> = engines.iter().map(|e| e.name()).collect();
    let mut r = Router::from_engines(engines, Policy::LeastLoaded);
    let arr = classed_arrivals(Arrival::Poisson { rate: 90.0 }, n_shard, 0.5, 17);
    let comps = r.run_classed(&arr);
    let [p50, p99, ..] = fleet_percentiles(&comps);
    println!(
        "mixed fleet + sharded swin-l-384 group: {n_shard} requests, p50 {p50:.1} ms, p99 {p99:.1} ms, shed {}",
        r.shed_count()
    );
    for (name, served) in names.iter().zip(r.served()) {
        println!("  {name}: {served} served");
    }
    println!();

    // routing overhead itself (L3 hot path)
    let mut r = Router::new(8, &TINY, AccelConfig::paper(), Policy::LeastLoaded);
    println!(
        "{}",
        bench_default("route() 8-card least-loaded", || {
            black_box(r.route(0));
        })
    );
}
