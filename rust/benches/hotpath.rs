//! Serving hot-path bench — the tracked perf trajectory of the router /
//! timing stack (`BENCH_hotpath.json` at the repo root).
//!
//! Drives the PR-3 bursty 2×Swin-T + 2×Swin-S workload scaled to a
//! 16-card fleet (8×T + 8×S) and ≥1M virtual-time arrivals, through
//! **both** router paths:
//!
//! * `before` — the pre-calendar algorithm, kept verbatim as the
//!   differential oracle ([`Router::run_classed_scan`]): full-fleet scan
//!   per arrival, `decompose`-allocating backlog pricing, per-call
//!   `Duration` round-trips, one global completion sort;
//! * `after`  — the event-calendar hot path ([`Router::run_classed`]):
//!   heap-driven advance, snapshotted u64 prices, incremental backlog
//!   cache, k-way-merge drain.
//!
//! The two must produce bit-identical percentiles (asserted here too —
//! a perf run that changed a modelled number is a failed run). A
//! counting global allocator reports allocations per arrival for each
//! path, and engine construction is timed both ways (one shared
//! `CostTable` per variant vs the pre-refactor per-card tables).
//!
//! Set `SWIN_BENCH_SHORT=1` for the CI smoke run.

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Instant;

use swin_fpga::accel::AccelConfig;
use swin_fpga::model::config::{SMALL, TINY};
use swin_fpga::report::Table;
use swin_fpga::server::router::{
    fleet_capacity_fps, fleet_percentiles, hetero_ts_fleet_scaled, LoadModel, Policy, Router,
};
use swin_fpga::server::workload::{classed_arrivals, Arrival, ClassedArrival};
use swin_fpga::server::{Engine, SimEngine};
use swin_fpga::util::json::Json;

/// Counting allocator: the allocations-per-arrival proxy. Counts every
/// heap allocation (alloc + realloc) made on the measured path.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.realloc(p, l, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const CARDS: usize = 16;

fn fleet(cfg: &AccelConfig) -> Vec<Box<dyn Engine>> {
    hetero_ts_fleet_scaled(cfg, CARDS / 4) // 8×Swin-T + 8×Swin-S
}

struct PathRun {
    arrivals_per_sec: f64,
    wall_s: f64,
    allocs_per_arrival: f64,
    percentiles: [f64; 4],
}

fn measure<F: FnMut() -> Vec<swin_fpga::server::router::FleetCompletion>>(
    n: usize,
    mut run: F,
) -> PathRun {
    let a0 = ALLOCS.load(Relaxed);
    let t0 = Instant::now();
    let comps = run();
    let wall = t0.elapsed().as_secs_f64();
    let allocs = ALLOCS.load(Relaxed) - a0;
    assert_eq!(comps.len(), n, "requests lost on the measured path");
    PathRun {
        arrivals_per_sec: n as f64 / wall,
        wall_s: wall,
        allocs_per_arrival: allocs as f64 / n as f64,
        percentiles: fleet_percentiles(&comps),
    }
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn path_json(r: &PathRun, construct_ms: f64) -> Json {
    obj(vec![
        ("arrivals_per_sec", Json::Num(r.arrivals_per_sec)),
        ("wall_s", Json::Num(r.wall_s)),
        ("allocs_per_arrival", Json::Num(r.allocs_per_arrival)),
        ("construct_fleet_ms", Json::Num(construct_ms)),
        ("p50_ms", Json::Num(r.percentiles[0])),
        ("p99_ms", Json::Num(r.percentiles[1])),
    ])
}

fn main() {
    let short = std::env::var("SWIN_BENCH_SHORT").is_ok();
    let n = if short { 50_000 } else { 1_000_000 };
    let cfg = AccelConfig::paper();

    // --- engine construction: shared cost tables vs per-card tables ----
    let t0 = Instant::now();
    let engines = fleet(&cfg);
    let construct_shared_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = Instant::now();
    let percard: Vec<Box<dyn Engine>> = (0..CARDS)
        .map(|i| {
            // the pre-refactor construction: every card lowers its own
            // schedule and converges its own steady costs
            let v = if i % 4 < 2 { &TINY } else { &SMALL };
            Box::new(SimEngine::new(i, v, cfg.clone(), 0.0)) as Box<dyn Engine>
        })
        .collect();
    let construct_percard_ms = t0.elapsed().as_secs_f64() * 1e3;
    drop(percard);

    // --- the PR-3 bursty workload at 16-card scale ----------------------
    let cap = fleet_capacity_fps(&engines);
    let arr: Vec<ClassedArrival> = classed_arrivals(
        Arrival::Bursty {
            high: 2.0 * cap,
            burst_s: 0.2,
            gap_s: 0.3,
        },
        n,
        0.5,
        31,
    );
    let mut r = Router::from_engines(engines, Policy::LeastLoaded).with_load(LoadModel::Backlog);

    // after: the event-calendar hot path
    let after = measure(n, || r.run_classed(&arr));
    // before: the retained pre-calendar oracle (scan + Duration pricing)
    let before = measure(n, || r.run_classed_scan(&arr));

    // a perf PR that changes a modelled number is a failed perf PR
    assert_eq!(
        before.percentiles, after.percentiles,
        "calendar router diverged from the scan oracle"
    );

    let speedup = after.arrivals_per_sec / before.arrivals_per_sec;
    let construct_ratio = construct_percard_ms / construct_shared_ms;

    let mut t = Table::new(
        &format!("serving hot path — {CARDS}-card 8×T+8×S fleet, {n} bursty arrivals"),
        &["path", "arrivals/s", "wall s", "allocs/arrival", "p50 ms", "p99 ms"],
    );
    for (name, p) in [("before (scan)", &before), ("after (calendar)", &after)] {
        t.row(&[
            name.into(),
            format!("{:.0}", p.arrivals_per_sec),
            format!("{:.2}", p.wall_s),
            format!("{:.2}", p.allocs_per_arrival),
            format!("{:.2}", p.percentiles[0]),
            format!("{:.2}", p.percentiles[1]),
        ]);
    }
    println!("{t}");
    println!(
        "speedup {speedup:.2}x arrivals/s; construction {construct_shared_ms:.1} ms shared vs \
         {construct_percard_ms:.1} ms per-card ({construct_ratio:.1}x)"
    );

    let json = obj(vec![
        ("bench", Json::Str("hotpath".into())),
        // schema note: `threads` records the execution width of the
        // measured path (this bench is the single-threaded calendar;
        // the sharded sweep lives in BENCH_fleet1b.json). `provenance`
        // distinguishes native runs from python-mirror estimates — the
        // first toolchain'd run overwrites any mirror numbers.
        ("threads", Json::Num(1.0)),
        (
            "provenance",
            Json::Str("native (cargo bench --bench hotpath)".into()),
        ),
        (
            "workload",
            obj(vec![
                ("cards", Json::Num(CARDS as f64)),
                ("fleet", Json::Str("8x swin-t + 8x swin-s".into())),
                ("arrivals", Json::Num(n as f64)),
                ("arrival_process", Json::Str("bursty 2x capacity".into())),
                ("interactive_share", Json::Num(0.5)),
                ("seed", Json::Num(31.0)),
            ]),
        ),
        ("before", path_json(&before, construct_percard_ms)),
        ("after", path_json(&after, construct_shared_ms)),
        (
            "speedup",
            obj(vec![
                ("arrivals_per_sec", Json::Num(speedup)),
                ("construction", Json::Num(construct_ratio)),
                (
                    // allocation-count ratio (denominator floored at the
                    // 0.01-alloc/arrival resolution so a fully
                    // allocation-free after-path stays finite)
                    "allocs_per_arrival",
                    Json::Num(before.allocs_per_arrival / after.allocs_per_arrival.max(0.01)),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_hotpath.json";
    std::fs::write(path, format!("{json}\n")).expect("write BENCH_hotpath.json");
    println!("wrote {path}");
}
