//! MMU hot-path microbench: functional GEMM throughput across the GEMM
//! shapes that dominate each Swin stage, plus the cycle model's predicted
//! MMU utilisation per shape. This is the L3 perf target of DESIGN.md
//! §Perf (simulated MMU tiles/s).

use swin_fpga::accel::{mmu::Mmu, tiling::IntMat, AccelConfig};
use swin_fpga::util::bench::{bench, black_box};
use swin_fpga::util::prng::Rng;

use std::time::Duration;

fn main() {
    let mmu = Mmu::new(AccelConfig::paper());
    let mut rng = Rng::new(3);

    // the shapes that dominate Swin-T per stage (rows×k×n)
    let shapes = [
        ("patch-embed 3136x48x96", 3136usize, 48usize, 96usize),
        ("qkv s0 3136x96x288", 3136, 96, 288),
        ("scores 49x32x49", 49, 32, 49),
        ("attn-v 49x49x32", 49, 49, 32),
        ("mlp1 s2 196x384x1536", 196, 384, 1536),
        ("head 1x768x1000", 1, 768, 1000),
    ];

    let mut total_tiles = 0f64;
    let mut total_time = 0f64;
    for (name, rows, k, n) in shapes {
        let a = IntMat::from_vec(rows, k, (0..rows * k).map(|_| rng.range_i32(-1500, 1500)).collect());
        let b = IntMat::from_vec(k, n, (0..k * n).map(|_| rng.range_i32(-1500, 1500)).collect());
        let r = bench(
            name,
            Duration::from_millis(100),
            Duration::from_millis(600),
            || {
                black_box(mmu.gemm(&a, &b, 12));
            },
        );
        let macs = (rows * k * n) as f64;
        let tiles = (rows.div_ceil(49) * n.div_ceil(32) * k.div_ceil(32)) as f64;
        println!(
            "{r}\n    {:>8.2} Mmac/s  {:>8.0} tiles/s  cycle-model {} cycles",
            macs / r.mean.as_secs_f64() / 1e6,
            tiles / r.mean.as_secs_f64(),
            mmu.gemm_cycles(rows, k, n),
        );
        total_tiles += tiles * r.iters as f64;
        total_time += r.mean.as_secs_f64() * r.iters as f64;
    }
    println!(
        "\naggregate functional-GEMM throughput: {:.0} tiles/s (verification \
         twin; the cycle simulator itself models ~4.6M tiles in 66 µs — \
         see EXPERIMENTS.md §Perf)",
        total_tiles / total_time
    );
}
