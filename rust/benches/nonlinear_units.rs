//! Nonlinear-unit benches + ablations (DESIGN.md §6):
//!
//! * SCU softmax and GCU GELU functional throughput;
//! * FMU grouped compare tree vs linear scan (cycle model, paper Fig. 7);
//! * GELU paper constant (0.000011b) vs 12-bit corrected constant —
//!   accuracy impact the paper does not report;
//! * softmax/GELU approximation error vs exact float (paper's <1%
//!   softmax-accuracy claim family);
//! * per-design comparison (baseline / QUARK / PEANO): functional
//!   throughput, per-op cycle model and error stats, written to
//!   BENCH_nonlinear.json.

use std::collections::BTreeMap;

use swin_fpga::accel::nonlinear::NlDesign;
use swin_fpga::accel::{gcu::Gcu, scu::Scu, AccelConfig};
use swin_fpga::approx::error::{gelu_stats_for, softmax_stats_for};
use swin_fpga::approx::gelu::{gelu_exact_f64, gelu_fixed};
use swin_fpga::approx::softmax::softmax_rows;
use swin_fpga::report::Table;
use swin_fpga::util::bench::{bench_default, black_box};
use swin_fpga::util::json::Json;
use swin_fpga::util::prng::Rng;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn main() {
    let cfg = AccelConfig::paper();
    let scu = Scu::new(cfg.clone());
    let gcu = Gcu::new(cfg);
    let mut rng = Rng::new(5);

    // --- throughput ------------------------------------------------------
    let scores: Vec<i32> = (0..49 * 49).map(|_| rng.range_i32(-2000, 2000)).collect();
    let r = bench_default("SCU softmax 49 rows x 49", || {
        black_box(scu.softmax(&scores, 49));
    });
    println!("{r}\n    {:.1} k rows/s", 49.0 / r.mean.as_secs_f64() / 1e3);

    let xs: Vec<i32> = (0..49 * 512).map(|_| rng.range_i32(-2000, 2000)).collect();
    let r = bench_default("GCU gelu 25088 elems", || {
        black_box(gcu.gelu(&xs));
    });
    println!("{r}\n    {:.1} M elems/s", xs.len() as f64 / r.mean.as_secs_f64() / 1e6);

    // --- FMU ablation (cycle model) ---------------------------------------
    let mut t = Table::new(
        "FMU: grouped tree vs linear scan (cycles to find max)",
        &["n", "grouped", "linear", "speedup"],
    );
    for n in [16usize, 49, 64, 128, 196] {
        let g = scu.fmu_cycles(n);
        let l = scu.fmu_cycles_linear(n);
        t.row(&[
            n.to_string(),
            g.to_string(),
            l.to_string(),
            format!("{:.1}x", l as f64 / g as f64),
        ]);
    }
    println!("{t}");

    // --- GELU constant ablation -------------------------------------------
    let mut t = Table::new(
        "GELU cubic constant: paper 0.046875 vs corrected 0.044678 (max |err| vs exact, by range)",
        &["|x| range", "paper", "corrected"],
    );
    for (lo, hi) in [(0.0f64, 1.0f64), (1.0, 2.0), (2.0, 4.0), (4.0, 8.0)] {
        let mut ep: f64 = 0.0;
        let mut ec: f64 = 0.0;
        let mut x = lo;
        while x < hi {
            for sx in [x, -x] {
                let q = (sx * 256.0).round() as i32;
                let want = gelu_exact_f64(sx);
                ep = ep.max((gelu_fixed(q, false) as f64 / 256.0 - want).abs());
                ec = ec.max((gelu_fixed(q, true) as f64 / 256.0 - want).abs());
            }
            x += 0.01;
        }
        t.row(&[
            format!("[{lo}, {hi})"),
            format!("{ep:.4}"),
            format!("{ec:.4}"),
        ]);
    }
    println!("{t}");

    // --- softmax approximation error ---------------------------------------
    let mut max_err: f64 = 0.0;
    let mut sum_dev: f64 = 0.0;
    let rows = 200;
    for r in 0..rows {
        let x: Vec<i32> = (0..49).map(|_| rng.range_i32(-1500, 1500)).collect();
        let out = softmax_rows(&x, 49);
        let xf: Vec<f64> = x.iter().map(|&v| v as f64 / 256.0).collect();
        let m = xf.iter().cloned().fold(f64::MIN, f64::max);
        let e: Vec<f64> = xf.iter().map(|&v| (v - m).exp()).collect();
        let s: f64 = e.iter().sum();
        for (o, ef) in out.iter().zip(&e) {
            max_err = max_err.max((*o as f64 / 32768.0 - ef / s).abs());
        }
        let rs: f64 = out.iter().map(|&v| v as f64 / 32768.0).sum();
        sum_dev = sum_dev.max((rs - 1.0).abs());
        let _ = r;
    }
    println!(
        "SCU approximation: max |p_i - exact| = {max_err:.4}, max |Σp - 1| = {sum_dev:.4} over {rows} random rows"
    );

    // --- per-design comparison ---------------------------------------------
    // throughput + per-op cycle model + error stats for each registered
    // nonlinear-unit design, dumped to BENCH_nonlinear.json
    let mut t = Table::new(
        "nonlinear-unit designs: per-op cycles + error (softmax 9408 rows x 49 / gelu 1229312)",
        &["design", "softmax cy", "gelu cy", "softmax max err", "gelu max err", "rows/s (functional)"],
    );
    let mut design_rows: Vec<Json> = Vec::new();
    for d in NlDesign::ALL {
        let cfg = AccelConfig::paper().nonlinear(d);
        let scu_d = Scu::new(cfg.clone());
        let nd = d.design();
        let smax_cy = scu_d.softmax_cycles(9408, 49);
        let gelu_cy = Gcu::new(cfg.clone()).gelu_cycles(1_229_312);
        let s = softmax_stats_for(
            |row, out| out.copy_from_slice(&nd.softmax(row, row.len())),
            100,
            49,
            3.0,
            9,
        );
        let g = gelu_stats_for(|q| nd.gelu(&[q])[0], -4.0, 4.0, 0.01);
        let r = bench_default(&format!("softmax 49x49 [{}]", d.name()), || {
            black_box(scu_d.softmax(&scores, 49));
        });
        let rows_per_s = 49.0 / r.mean.as_secs_f64();
        t.row(&[
            d.name().to_string(),
            smax_cy.to_string(),
            gelu_cy.to_string(),
            format!("{:.5}", s.max_err),
            format!("{:.5}", g.max_abs),
            format!("{:.1} k", rows_per_s / 1e3),
        ]);
        design_rows.push(obj(vec![
            ("design", Json::Str(d.name().into())),
            ("softmax_cycles_9408x49", Json::Num(smax_cy as f64)),
            ("gelu_cycles_1229312", Json::Num(gelu_cy as f64)),
            ("softmax_max_err", Json::Num(s.max_err)),
            ("softmax_mean_err", Json::Num(s.mean_err)),
            ("softmax_max_sum_dev", Json::Num(s.max_sum_dev)),
            ("gelu_max_abs", Json::Num(g.max_abs)),
            ("gelu_mean_abs", Json::Num(g.mean_abs)),
            ("functional_rows_per_s", Json::Num(rows_per_s)),
        ]));
    }
    println!("{t}");

    let json = obj(vec![
        ("bench", Json::Str("nonlinear_units".into())),
        (
            "provenance",
            Json::Str("native (cargo bench --bench nonlinear_units)".into()),
        ),
        (
            "workload",
            obj(vec![
                ("softmax_shape", Json::Str("9408 rows x 49 (swin-t attention)".into())),
                ("gelu_elems", Json::Num(1_229_312.0)),
                ("error_harness", Json::Str("softmax_stats_for(100x49, sigma=3, seed=9) / gelu_stats_for([-4,4], 0.01)".into())),
            ]),
        ),
        ("designs", Json::Arr(design_rows)),
    ]);
    let path = "BENCH_nonlinear.json";
    std::fs::write(path, format!("{json}\n")).expect("write BENCH_nonlinear.json");
    println!("wrote {path}");
}
