//! Quantisation analysis bench (paper §V.C): SQNR per Q-format over
//! realistic tensor distributions, calibration behaviour, and the
//! approximation-error summary for every hardware shortcut (§III.B).

use swin_fpga::approx::error::{
    exp2_max_rel_error, gelu_error_stats, log2_max_abs_error, pwl_exp2_error,
    softmax_error_stats,
};
use swin_fpga::model::quantize::{calibrate_frac, saturation_rate, sqnr_db};
use swin_fpga::report::Table;
use swin_fpga::util::prng::Rng;

fn main() {
    // --- SQNR per format over tensor families ------------------------------
    let mut rng = Rng::new(17);
    let families = [
        ("activations N(0,1)", rng.normal_vec(50_000, 1.0)),
        ("residual stream N(0,2.5)", rng.normal_vec(50_000, 2.5)),
        ("fused weights N(0,0.05)", rng.normal_vec(50_000, 0.05)),
        ("attn logits N(0,4)", rng.normal_vec(50_000, 4.0)),
    ];
    let mut t = Table::new(
        "SQNR (dB) by Q-format (int16 storage)",
        &["tensor", "Q7.8", "Q5.10", "Q3.12", "Q1.14", "calibrated"],
    );
    for (name, xs) in &families {
        let (best_frac, best_sqnr) = calibrate_frac(xs, 1e-4);
        t.row(&[
            name.to_string(),
            format!("{:.1}", sqnr_db(xs, 8)),
            format!("{:.1}", sqnr_db(xs, 10)),
            format!("{:.1}", sqnr_db(xs, 12)),
            format!("{:.1}", sqnr_db(xs, 14)),
            format!("Q{}.{} ({:.1} dB)", 15 - best_frac, best_frac, best_sqnr),
        ]);
    }
    println!("{t}");
    println!(
        "saturation at Q1.14 for N(0,2.5): {:.2}% (why activations sit at Q7.8)",
        saturation_rate(&families[1].1, 14) * 100.0
    );

    // --- approximation-error summary ----------------------------------------
    let mut t = Table::new(
        "hardware approximation errors (paper §III.B shortcuts)",
        &["approximation", "metric", "value"],
    );
    t.row(&["EU 2^v (8-seg PWL + shift)".into(), "max rel, v∈[-6,6]".into(),
            format!("{:.2e}", exp2_max_rel_error(-6.0, 6.0, 4001))]);
    t.row(&["LOD log2 (Eq. 12)".into(), "max abs".into(),
            format!("{:.4}", log2_max_abs_error(500))]);
    let (sm_err, sm_sum) = softmax_error_stats(300, 49, 3.0, 23);
    t.row(&["SCU softmax".into(), "max |p−exact| / |Σp−1|".into(),
            format!("{sm_err:.4} / {sm_sum:.4}")]);
    let (g_abs, g_rel) = gelu_error_stats(-8.0, 8.0, 0.005, false);
    t.row(&["GCU GELU (paper consts)".into(), "max abs / rel(|y|≥.25)".into(),
            format!("{g_abs:.4} / {g_rel:.4}")]);
    let (gc_abs, gc_rel) = gelu_error_stats(-8.0, 8.0, 0.005, true);
    t.row(&["GCU GELU (corrected cubic)".into(), "max abs / rel".into(),
            format!("{gc_abs:.4} / {gc_rel:.4}")]);
    println!("{t}");

    // --- PWL segment sweep ---------------------------------------------------
    let mut t = Table::new(
        "EU piecewise-linear segment sweep (max rel error of 2^f, f∈[0,1))",
        &["segments", "max rel error", "note"],
    );
    for segs in [2usize, 4, 8, 16, 32] {
        t.row(&[
            segs.to_string(),
            format!("{:.2e}", pwl_exp2_error(segs, 8000)),
            if segs == 8 { "paper (3 index bits)".into() } else { String::new() },
        ]);
    }
    println!("{t}");
}
