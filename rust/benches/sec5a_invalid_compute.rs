//! Bench/report for paper §V.A: the invalid-computation analysis
//! (Eq. 17 closed form, the paper's 1.2% claim, and the exact per-op
//! graph count including patch-embed/head padding).

use swin_fpga::model::flops::{invalid_fraction_block, invalid_fraction_block_with_co};
use swin_fpga::report::{self, Table};

fn main() {
    println!("{}", report::sec5a_invalid());

    // the paper's exact claim: Eq. 17 at the base channel count
    let mut t = Table::new(
        "Eq. 17 at base C (the paper computes U = 1.2%)",
        &["C", "U"],
    );
    for c in [96usize, 128] {
        t.row(&[c.to_string(), format!("{:.2}%", invalid_fraction_block(c, 7) * 100.0)]);
    }
    println!("{t}");

    let mut t = Table::new("Eq. 17 vs c_o (ablation)", &["c_o", "U(C=96)"]);
    for co in [8usize, 16, 32, 64, 128] {
        t.row(&[
            co.to_string(),
            format!("{:.2}%", invalid_fraction_block_with_co(96, 7, co) * 100.0),
        ]);
    }
    println!("{t}");
}
