//! Bench/report for paper Table III: submodule resource utilisation.
//! (Resource numbers are model outputs, not timings; the bench also times
//! the functional units the resources pay for.)

use swin_fpga::accel::{gcu::Gcu, mmu::Mmu, scu::Scu, tiling::IntMat, AccelConfig};
use swin_fpga::report;
use swin_fpga::util::bench::{bench_default, black_box};
use swin_fpga::util::prng::Rng;

fn main() {
    println!("{}", report::table3_submodules());

    let cfg = AccelConfig::paper();
    let mut rng = Rng::new(1);

    let mmu = Mmu::new(cfg.clone());
    let a = IntMat::from_vec(49, 96, (0..49 * 96).map(|_| rng.range_i32(-2000, 2000)).collect());
    let b = IntMat::from_vec(96, 64, (0..96 * 64).map(|_| rng.range_i32(-2000, 2000)).collect());
    println!("{}", bench_default("MMU functional gemm 49x96x64", || {
        black_box(mmu.gemm(&a, &b, 12));
    }));

    let scu = Scu::new(cfg.clone());
    let scores: Vec<i32> = (0..49 * 49).map(|_| rng.range_i32(-2000, 2000)).collect();
    println!("{}", bench_default("SCU functional softmax 49x49", || {
        black_box(scu.softmax(&scores, 49));
    }));

    let gcu = Gcu::new(cfg);
    let xs: Vec<i32> = (0..49 * 128).map(|_| rng.range_i32(-2000, 2000)).collect();
    println!("{}", bench_default("GCU functional gelu 49x128", || {
        black_box(gcu.gelu(&xs));
    }));
}
