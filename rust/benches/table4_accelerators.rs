//! Bench/report for paper Table IV: whole-accelerator resources per
//! variant, plus device-fit checks and the buffer-plan breakdown.

use swin_fpga::accel::buffers::BufferPlan;
use swin_fpga::accel::resources::{accelerator_resources, XCZU19EG};
use swin_fpga::accel::AccelConfig;
use swin_fpga::report::{self, Table};

fn main() {
    println!("{}", report::table4_accelerators());

    let cfg = AccelConfig::paper();
    let mut t = Table::new(
        "Buffer plan breakdown (BRAM36)",
        &["Model", "FIB", "WeightBuf", "BiasBuf", "ILB", "OutputBuf", "total"],
    );
    for v in report::paper_variants() {
        let plan = BufferPlan::for_variant(v);
        let mut cells = vec![v.name.to_string()];
        for b in &plan.buffers {
            cells.push(b.bram36().to_string());
        }
        cells.push(plan.total_bram36().to_string());
        t.row(&cells);
    }
    println!("{t}");

    for v in report::paper_variants() {
        let r = accelerator_resources(v, &cfg);
        assert!(r.fits(&XCZU19EG), "{} does not fit!", v.name);
        println!("{}: fits XCZU19EG ✓ ({} DSP of {})", v.name, r.dsp, XCZU19EG.dsps);
    }
}
