//! Bench/report for paper Table V: FPS / GOPS / power of the simulated
//! accelerator vs the paper's reported numbers and related work, plus
//! timing of the simulator itself (the L3 hot path of `simulate`).

use swin_fpga::accel::sim::Simulator;
use swin_fpga::accel::AccelConfig;
use swin_fpga::report;
use swin_fpga::util::bench::{bench_default, black_box};

fn main() {
    println!("{}", report::table5_comparison());

    // paper-vs-sim deltas, explicitly
    for v in report::paper_variants() {
        let r = Simulator::new(v, AccelConfig::paper()).simulate_inference();
        let paper = report::paper_fps(v.name);
        println!(
            "{:<10} sim {:>6.1} FPS vs paper {:>6.1} FPS  ({:+.1}%)",
            v.name,
            r.fps(),
            paper,
            (r.fps() - paper) / paper * 100.0
        );
    }

    // how fast is the cycle model itself?
    for v in report::paper_variants() {
        let sim = Simulator::new(v, AccelConfig::paper());
        println!(
            "{}",
            bench_default(&format!("simulate_inference {}", v.name), || {
                black_box(sim.simulate_inference());
            })
        );
    }
}
