//! On-chip buffer model (paper Fig. 3): FIB, weight/bias buffers, the ILB
//! collection, output buffer — each mapped to BRAM36 blocks on the
//! XCZU19EG (984 × 36 Kb).
//!
//! The model answers two questions the paper's design implies:
//! 1. *capacity*: does a variant's worst-case working set fit the
//!    buffers (Table IV sizes BRAM differently for Swin-B)?
//! 2. *BRAM cost*: how many BRAM36 the configuration consumes
//!    (feeds [`super::resources`]).

use crate::model::config::SwinVariant;

/// Bits per BRAM36 block.
pub const BRAM36_BITS: usize = 36 * 1024;

/// One logical buffer: byte capacity + banking (each bank is ported
/// separately and therefore occupies at least one BRAM).
#[derive(Debug, Clone)]
pub struct BufferSpec {
    pub name: &'static str,
    pub bytes: usize,
    pub banks: usize,
}

impl BufferSpec {
    /// BRAM36 blocks consumed: per-bank ceiling (hardware cannot split a
    /// bank across a partially-used block shared with another bank).
    pub fn bram36(&self) -> usize {
        let per_bank_bytes = self.bytes.div_ceil(self.banks);
        self.banks * (per_bank_bytes * 8).div_ceil(BRAM36_BITS)
    }
}

/// The accelerator's buffer complement, sized for a variant.
#[derive(Debug, Clone)]
pub struct BufferPlan {
    pub buffers: Vec<BufferSpec>,
}

impl BufferPlan {
    /// Size buffers for a variant's worst-case tile working set:
    ///
    /// * FIB — one stage-0 feature-map stripe (window rows × max C)
    /// * weight buffer — double-buffered c_i×c_o weight tiles plus a
    ///   streaming window of the largest layer row
    /// * bias buffer — largest bias vector
    /// * ILB — QKV + scores + probs for one window batch (the paper's
    ///   "collection of intermediate layer buffers")
    /// * output buffer — one M²×c_o accumulation tile bank per PE group
    pub fn for_variant(v: &SwinVariant) -> Self {
        let m2 = v.window * v.window;
        let cmax = v.final_dim();
        let hidden_max = v.mlp_ratio * cmax;
        let buffers = vec![
            BufferSpec {
                name: "FIB",
                // one window-row stripe of the widest feature map
                bytes: 2 * v.stage_resolution(0) * v.window * v.embed_dim.max(cmax / 4),
                banks: 4,
            },
            BufferSpec {
                name: "WeightBuf",
                // double-buffered stream window: 2 × c_i × widest layer
                bytes: 2 * 2 * 32 * hidden_max,
                banks: 8,
            },
            BufferSpec {
                name: "BiasBuf",
                bytes: 2 * hidden_max,
                banks: 1,
            },
            BufferSpec {
                name: "ILB",
                // Q,K,V (3·M²·C) + scores/probs (2·M²·M²·heads-batch) for
                // one window round at the widest stage
                bytes: 2 * (3 * m2 * cmax + 2 * m2 * m2 * v.num_heads[v.num_stages() - 1]),
                banks: 8,
            },
            BufferSpec {
                name: "OutputBuf",
                // M² × c_o accumulation tiles, i32, double-buffered
                bytes: 2 * 4 * m2 * 32,
                banks: 2,
            },
        ];
        BufferPlan { buffers }
    }

    pub fn total_bytes(&self) -> usize {
        self.buffers.iter().map(|b| b.bytes).sum()
    }

    pub fn total_bram36(&self) -> usize {
        self.buffers.iter().map(|b| b.bram36()).sum()
    }

    /// Does the plan fit a device with `avail` BRAM36 blocks?
    pub fn fits(&self, avail: usize) -> bool {
        self.total_bram36() <= avail
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, MICRO, SMALL, TINY};

    #[test]
    fn bram_rounding_per_bank() {
        let b = BufferSpec {
            name: "x",
            bytes: 4609, // 1 byte over one BRAM36 (4608 B)
            banks: 1,
        };
        assert_eq!(b.bram36(), 2);
        let b2 = BufferSpec {
            name: "y",
            bytes: 4609,
            banks: 4,
        };
        // 1153 B/bank → 1 BRAM each
        assert_eq!(b2.bram36(), 4);
    }

    #[test]
    fn plans_fit_the_xczu19eg() {
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let p = BufferPlan::for_variant(v);
            assert!(p.fits(984), "{}: {} BRAM", v.name, p.total_bram36());
        }
    }

    #[test]
    fn base_needs_more_bram_than_tiny() {
        // Table IV: Swin-B uses 338 BRAM vs 244 for T/S — ordering must hold
        let t = BufferPlan::for_variant(&TINY).total_bram36();
        let b = BufferPlan::for_variant(&BASE).total_bram36();
        assert!(b > t, "tiny={t} base={b}");
    }

    #[test]
    fn tiny_and_small_identical() {
        // Table IV lists identical resources for Swin-T and Swin-S
        assert_eq!(
            BufferPlan::for_variant(&TINY).total_bram36(),
            BufferPlan::for_variant(&SMALL).total_bram36()
        );
    }
}
