//! On-chip buffer model (paper Fig. 3): FIB, weight/bias buffers, the ILB
//! collection, output buffer — each mapped to BRAM36 blocks on the
//! XCZU19EG (984 × 36 Kb).
//!
//! The model answers two questions the paper's design implies:
//! 1. *capacity*: does a variant's worst-case working set fit the
//!    buffers (Table IV sizes BRAM differently for Swin-B)?
//! 2. *BRAM cost*: how many BRAM36 the configuration consumes
//!    (feeds [`super::resources`]).

use crate::model::config::SwinVariant;

/// Bits per BRAM36 block.
pub const BRAM36_BITS: usize = 36 * 1024;

/// BRAM36 blocks on the paper's target device (XCZU19EG) — the per-card
/// capacity every [`BufferPlan::fits`] verdict and the default
/// `ShardPlan` budget are judged against.
pub const XCZU19EG_BRAM36: usize = 984;

/// One logical buffer: byte capacity + banking (each bank is ported
/// separately and therefore occupies at least one BRAM).
#[derive(Debug, Clone)]
pub struct BufferSpec {
    pub name: &'static str,
    pub bytes: usize,
    pub banks: usize,
}

impl BufferSpec {
    /// BRAM36 blocks consumed: per-bank ceiling (hardware cannot split a
    /// bank across a partially-used block shared with another bank).
    pub fn bram36(&self) -> usize {
        let per_bank_bytes = self.bytes.div_ceil(self.banks);
        self.banks * (per_bank_bytes * 8).div_ceil(BRAM36_BITS)
    }
}

/// The accelerator's buffer complement, sized for a variant.
#[derive(Debug, Clone)]
pub struct BufferPlan {
    pub buffers: Vec<BufferSpec>,
    /// Per-stage double-buffered weight-stream window in bytes: two
    /// c_i=32-deep row buffers of the stage's widest layer (the 4·C_s MLP
    /// hidden row). One in-flight unit stream reserves one window, so the
    /// weight buffer's capacity over this window is the stage's prefetch
    /// headroom — see [`Self::prefetch_depth`].
    pub stage_stream_windows: Vec<usize>,
}

impl BufferPlan {
    /// Size buffers for a variant's worst-case tile working set:
    ///
    /// * FIB — one stage-0 feature-map stripe (window rows × max C)
    /// * weight buffer — double-buffered c_i×c_o weight tiles plus a
    ///   streaming window of the largest layer row
    /// * bias buffer — largest bias vector
    /// * ILB — QKV + scores + probs for one window batch (the paper's
    ///   "collection of intermediate layer buffers")
    /// * output buffer — one M²×c_o accumulation tile bank per PE group
    pub fn for_variant(v: &SwinVariant) -> Self {
        let m2 = v.window * v.window;
        let cmax = v.final_dim();
        let hidden_max = v.mlp_ratio * cmax;
        // per-stage stream windows; the weight buffer is sized as a
        // double window of the *last* (widest) stage, so earlier stages
        // fit proportionally more in-flight streams
        let stage_stream_windows: Vec<usize> = (0..v.num_stages())
            .map(|s| 2 * 32 * (v.mlp_ratio * v.stage_dim(s)))
            .collect();
        let buffers = vec![
            BufferSpec {
                name: "FIB",
                // one window-row stripe of the widest feature map
                bytes: 2 * v.stage_resolution(0) * v.window * v.embed_dim.max(cmax / 4),
                banks: 4,
            },
            BufferSpec {
                name: "WeightBuf",
                // double-buffered stream window: 2 × c_i × widest layer
                bytes: 2 * 2 * 32 * hidden_max,
                banks: 8,
            },
            BufferSpec {
                name: "BiasBuf",
                bytes: 2 * hidden_max,
                banks: 1,
            },
            BufferSpec {
                name: "ILB",
                // Q,K,V (3·M²·C) + scores/probs (2·M²·M²·heads-batch) for
                // one window round at the widest stage
                bytes: 2 * (3 * m2 * cmax + 2 * m2 * m2 * v.num_heads[v.num_stages() - 1]),
                banks: 8,
            },
            BufferSpec {
                name: "OutputBuf",
                // M² × c_o accumulation tiles, i32, double-buffered
                bytes: 2 * 4 * m2 * 32,
                banks: 2,
            },
        ];
        BufferPlan {
            buffers,
            stage_stream_windows,
        }
    }

    /// Size buffers for a card hosting only stages `lo..hi` of a sharded
    /// variant — the same formulas as [`Self::for_variant`] with every
    /// "widest stage" maximum restricted to the hosted range (the card's
    /// bitstream is generated for its own stages, not the whole model).
    /// `for_stage_range(v, 0, v.num_stages())` is bit-identical to
    /// `for_variant(v)`. The returned plan's `stage_stream_windows` are
    /// indexed *relative to `lo`* (entry 0 is stage `lo`).
    pub fn for_stage_range(v: &SwinVariant, lo: usize, hi: usize) -> Self {
        assert!(lo < hi && hi <= v.num_stages(), "bad stage range {lo}..{hi}");
        let m2 = v.window * v.window;
        let cmax = v.stage_dim(hi - 1);
        let hidden_max = v.mlp_ratio * cmax;
        let stage_stream_windows: Vec<usize> = (lo..hi)
            .map(|s| 2 * 32 * (v.mlp_ratio * v.stage_dim(s)))
            .collect();
        let buffers = vec![
            BufferSpec {
                name: "FIB",
                // a stripe of the shard's *input* feature map, wide
                // enough for the widest hosted quarter-resolution map
                bytes: 2 * v.stage_resolution(lo) * v.window * v.stage_dim(lo).max(cmax / 4),
                banks: 4,
            },
            BufferSpec {
                name: "WeightBuf",
                bytes: 2 * 2 * 32 * hidden_max,
                banks: 8,
            },
            BufferSpec {
                name: "BiasBuf",
                bytes: 2 * hidden_max,
                banks: 1,
            },
            BufferSpec {
                name: "ILB",
                bytes: 2 * (3 * m2 * cmax + 2 * m2 * m2 * v.num_heads[hi - 1]),
                banks: 8,
            },
            BufferSpec {
                name: "OutputBuf",
                bytes: 2 * 4 * m2 * 32,
                banks: 2,
            },
        ];
        BufferPlan {
            buffers,
            stage_stream_windows,
        }
    }

    /// The weight buffer spec (the double-buffered stream staging area).
    pub fn weight_buffer(&self) -> &BufferSpec {
        self.buffers
            .iter()
            .find(|b| b.name == "WeightBuf")
            .expect("plan has a weight buffer")
    }

    /// One stage's in-flight weight-stream window in bytes. Out-of-range
    /// stage indices are a caller bug (the PR-2 clamp-bug precedent):
    /// they debug-assert, and in release builds fall back to the last
    /// stage's window rather than panic mid-serving.
    pub fn stream_window_bytes(&self, stage: usize) -> usize {
        debug_assert!(
            stage < self.stage_stream_windows.len(),
            "stage {stage} out of range for a {}-stage plan",
            self.stage_stream_windows.len()
        );
        match self.stage_stream_windows.get(stage) {
            Some(&w) => w,
            None => self.stage_stream_windows.last().copied().unwrap_or(0),
        }
    }

    /// Prefetch headroom of a stage: how many of its unit weight streams
    /// the weight buffer can host at once (≥ 1). This is the capacity
    /// constraint the pipeline IR's prefetch gate consults — a unit's
    /// stream may not start until the unit `depth` places ahead of it has
    /// released its slot. The last stage is double-buffered (depth 2) by
    /// construction; earlier stages have narrower windows and therefore
    /// deeper headroom.
    pub fn prefetch_depth(&self, stage: usize) -> usize {
        let window = self.stream_window_bytes(stage).max(1);
        (self.weight_buffer().bytes / window).max(1)
    }

    /// Per-stage prefetch depths (see [`Self::prefetch_depth`]).
    pub fn prefetch_depths(&self) -> Vec<usize> {
        (0..self.stage_stream_windows.len())
            .map(|s| self.prefetch_depth(s))
            .collect()
    }

    pub fn total_bytes(&self) -> usize {
        self.buffers.iter().map(|b| b.bytes).sum()
    }

    pub fn total_bram36(&self) -> usize {
        self.buffers.iter().map(|b| b.bram36()).sum()
    }

    /// Does the plan fit a device with `avail` BRAM36 blocks?
    pub fn fits_device(&self, avail: usize) -> bool {
        self.total_bram36() <= avail
    }

    /// Capacity verdict against the paper's target card: does this plan
    /// fit one XCZU19EG? (`false` for Swin-L/384 — the scenario the
    /// `ShardPlan` layer exists for.)
    pub fn fits(&self) -> bool {
        self.fits_device(XCZU19EG_BRAM36)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, MICRO, SMALL, TINY};

    #[test]
    fn bram_rounding_per_bank() {
        let b = BufferSpec {
            name: "x",
            bytes: 4609, // 1 byte over one BRAM36 (4608 B)
            banks: 1,
        };
        assert_eq!(b.bram36(), 2);
        let b2 = BufferSpec {
            name: "y",
            bytes: 4609,
            banks: 4,
        };
        // 1153 B/bank → 1 BRAM each
        assert_eq!(b2.bram36(), 4);
    }

    #[test]
    fn bram_rounding_edge_cases() {
        // a bank exactly filling one BRAM36 consumes exactly one block
        let exact = BufferSpec {
            name: "exact",
            bytes: 4608, // 36 Kb = 4608 B
            banks: 1,
        };
        assert_eq!(exact.bram36(), 1);
        let exact4 = BufferSpec {
            name: "exact4",
            bytes: 4 * 4608,
            banks: 4,
        };
        assert_eq!(exact4.bram36(), 4);
        // a zero-byte buffer consumes no BRAM regardless of banking
        for banks in [1usize, 2, 8] {
            let z = BufferSpec {
                name: "z",
                bytes: 0,
                banks,
            };
            assert_eq!(z.bram36(), 0, "banks={banks}");
        }
    }

    #[test]
    fn plans_fit_the_xczu19eg() {
        // Swin-T/S/B (and micro) must fit the XCZU19EG's 984-block budget
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let p = BufferPlan::for_variant(v);
            assert!(p.fits(), "{}: {} BRAM", v.name, p.total_bram36());
            assert!(p.fits_device(XCZU19EG_BRAM36), "{}", v.name);
            assert!(p.total_bram36() > 0, "{}", v.name);
        }
    }

    #[test]
    fn large_384_does_not_fit_one_card() {
        use crate::model::config::{BASE_384, LARGE, LARGE_384, TINY_384};
        // Swin-L at 224 still fits; the 12×12-window 384 models blow the
        // ILB (scores/probs grow as M⁴·heads) — the sharding motivation
        assert!(BufferPlan::for_variant(&LARGE).fits());
        assert!(BufferPlan::for_variant(&TINY_384).fits());
        assert!(!BufferPlan::for_variant(&BASE_384).fits());
        assert!(!BufferPlan::for_variant(&LARGE_384).fits());
        // exact totals pin the capacity model (mirror-verified)
        assert_eq!(BufferPlan::for_variant(&BASE_384).total_bram36(), 1026);
        assert_eq!(BufferPlan::for_variant(&LARGE_384).total_bram36(), 1531);
    }

    #[test]
    fn full_stage_range_plan_is_bit_identical_to_for_variant() {
        use crate::model::config::{BASE_384, LARGE_384};
        for v in [&MICRO, &TINY, &SMALL, &BASE, &BASE_384, &LARGE_384] {
            let full = BufferPlan::for_variant(v);
            let ranged = BufferPlan::for_stage_range(v, 0, v.num_stages());
            assert_eq!(ranged.stage_stream_windows, full.stage_stream_windows, "{}", v.name);
            for (a, b) in ranged.buffers.iter().zip(&full.buffers) {
                assert_eq!((a.name, a.bytes, a.banks), (b.name, b.bytes, b.banks), "{}", v.name);
            }
        }
    }

    #[test]
    fn stage_range_plans_shrink_and_tile_the_capacity() {
        use crate::model::config::BASE_384;
        // Swin-B/384 does not fit whole, but a [0,3) + [3,4) split does —
        // the clean two-card exemplar the greedy partition should find
        let head = BufferPlan::for_stage_range(&BASE_384, 0, 3);
        let tail = BufferPlan::for_stage_range(&BASE_384, 3, 4);
        assert!(head.fits(), "{} BRAM", head.total_bram36());
        assert!(tail.fits(), "{} BRAM", tail.total_bram36());
        // a sub-range never costs more BRAM than the full plan
        let full = BufferPlan::for_variant(&BASE_384).total_bram36();
        assert!(head.total_bram36() < full);
        assert!(tail.total_bram36() < full);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "out of range")]
    fn stream_window_out_of_range_asserts() {
        let p = BufferPlan::for_variant(&TINY);
        let _ = p.stream_window_bytes(4);
    }

    #[test]
    fn prefetch_depths_double_buffered_at_least() {
        // the last stage is exactly double-buffered by construction;
        // earlier stages have narrower windows, hence deeper headroom
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let p = BufferPlan::for_variant(v);
            let depths = p.prefetch_depths();
            assert_eq!(depths.len(), v.num_stages(), "{}", v.name);
            assert_eq!(*depths.last().unwrap(), 2, "{}", v.name);
            // monotone non-increasing along stages (windows widen)
            for w in depths.windows(2) {
                assert!(w[0] >= w[1], "{}: {:?}", v.name, depths);
            }
            assert!(depths[0] >= 2, "{}", v.name);
        }
        // the paper variants share the 16/8/4/2 ladder
        assert_eq!(BufferPlan::for_variant(&TINY).prefetch_depths(), vec![16, 8, 4, 2]);
    }

    /// Regression: the `for_variant` headroom feeding the pipeline IR's
    /// prefetch gate must be monotone in the variant's window and
    /// embed_dim — a wider model may never report *more* slack per stage,
    /// and growing the attention window may never shrink the weight-path
    /// headroom (it pressures the ILB/FIB, not the weight buffer).
    #[test]
    fn headroom_monotone_in_window_and_embed_dim() {
        fn variant(window: usize, embed_dim: usize) -> SwinVariant {
            SwinVariant {
                name: "probe",
                img_size: 224,
                patch_size: 4,
                in_chans: 3,
                embed_dim,
                depths: &[2, 2, 6, 2],
                num_heads: &[3, 6, 12, 24],
                window,
                mlp_ratio: 4,
                num_classes: 1000,
            }
        }
        // embed_dim ↑ → per-stage stream windows widen (non-decreasing)
        // and prefetch depths shrink (non-increasing), stage by stage
        let mut prev: Option<BufferPlan> = None;
        for dim in [48usize, 96, 192, 384] {
            let p = BufferPlan::for_variant(&variant(7, dim));
            if let Some(q) = &prev {
                for s in 0..4 {
                    assert!(p.stream_window_bytes(s) >= q.stream_window_bytes(s), "dim={dim}");
                    assert!(p.prefetch_depth(s) <= q.prefetch_depth(s), "dim={dim}");
                }
            }
            prev = Some(p);
        }
        // window ↑ → weight-path headroom unchanged, on-chip pressure
        // (ILB/FIB bytes) non-decreasing
        let mut prev: Option<BufferPlan> = None;
        for m in [7usize, 14, 28] {
            let p = BufferPlan::for_variant(&variant(m, 96));
            if let Some(q) = &prev {
                for s in 0..4 {
                    assert_eq!(p.prefetch_depth(s), q.prefetch_depth(s), "M={m}");
                }
                assert!(p.total_bytes() >= q.total_bytes(), "M={m}");
            }
            prev = Some(p);
        }
    }

    #[test]
    fn base_needs_more_bram_than_tiny() {
        // Table IV: Swin-B uses 338 BRAM vs 244 for T/S — ordering must hold
        let t = BufferPlan::for_variant(&TINY).total_bram36();
        let b = BufferPlan::for_variant(&BASE).total_bram36();
        assert!(b > t, "tiny={t} base={b}");
    }

    #[test]
    fn tiny_and_small_identical() {
        // Table IV lists identical resources for Swin-T and Swin-S
        assert_eq!(
            BufferPlan::for_variant(&TINY).total_bram36(),
            BufferPlan::for_variant(&SMALL).total_bram36()
        );
    }
}
