//! On-chip buffer model (paper Fig. 3): FIB, weight/bias buffers, the ILB
//! collection, output buffer — each mapped to BRAM36 blocks on the
//! XCZU19EG (984 × 36 Kb).
//!
//! The model answers two questions the paper's design implies:
//! 1. *capacity*: does a variant's worst-case working set fit the
//!    buffers (Table IV sizes BRAM differently for Swin-B)?
//! 2. *BRAM cost*: how many BRAM36 the configuration consumes
//!    (feeds [`super::resources`]).

use crate::model::config::SwinVariant;

/// Bits per BRAM36 block.
pub const BRAM36_BITS: usize = 36 * 1024;

/// One logical buffer: byte capacity + banking (each bank is ported
/// separately and therefore occupies at least one BRAM).
#[derive(Debug, Clone)]
pub struct BufferSpec {
    pub name: &'static str,
    pub bytes: usize,
    pub banks: usize,
}

impl BufferSpec {
    /// BRAM36 blocks consumed: per-bank ceiling (hardware cannot split a
    /// bank across a partially-used block shared with another bank).
    pub fn bram36(&self) -> usize {
        let per_bank_bytes = self.bytes.div_ceil(self.banks);
        self.banks * (per_bank_bytes * 8).div_ceil(BRAM36_BITS)
    }
}

/// The accelerator's buffer complement, sized for a variant.
#[derive(Debug, Clone)]
pub struct BufferPlan {
    pub buffers: Vec<BufferSpec>,
    /// Per-stage double-buffered weight-stream window in bytes: two
    /// c_i=32-deep row buffers of the stage's widest layer (the 4·C_s MLP
    /// hidden row). One in-flight unit stream reserves one window, so the
    /// weight buffer's capacity over this window is the stage's prefetch
    /// headroom — see [`Self::prefetch_depth`].
    pub stage_stream_windows: Vec<usize>,
}

impl BufferPlan {
    /// Size buffers for a variant's worst-case tile working set:
    ///
    /// * FIB — one stage-0 feature-map stripe (window rows × max C)
    /// * weight buffer — double-buffered c_i×c_o weight tiles plus a
    ///   streaming window of the largest layer row
    /// * bias buffer — largest bias vector
    /// * ILB — QKV + scores + probs for one window batch (the paper's
    ///   "collection of intermediate layer buffers")
    /// * output buffer — one M²×c_o accumulation tile bank per PE group
    pub fn for_variant(v: &SwinVariant) -> Self {
        let m2 = v.window * v.window;
        let cmax = v.final_dim();
        let hidden_max = v.mlp_ratio * cmax;
        // per-stage stream windows; the weight buffer is sized as a
        // double window of the *last* (widest) stage, so earlier stages
        // fit proportionally more in-flight streams
        let stage_stream_windows: Vec<usize> = (0..v.num_stages())
            .map(|s| 2 * 32 * (v.mlp_ratio * v.stage_dim(s)))
            .collect();
        let buffers = vec![
            BufferSpec {
                name: "FIB",
                // one window-row stripe of the widest feature map
                bytes: 2 * v.stage_resolution(0) * v.window * v.embed_dim.max(cmax / 4),
                banks: 4,
            },
            BufferSpec {
                name: "WeightBuf",
                // double-buffered stream window: 2 × c_i × widest layer
                bytes: 2 * 2 * 32 * hidden_max,
                banks: 8,
            },
            BufferSpec {
                name: "BiasBuf",
                bytes: 2 * hidden_max,
                banks: 1,
            },
            BufferSpec {
                name: "ILB",
                // Q,K,V (3·M²·C) + scores/probs (2·M²·M²·heads-batch) for
                // one window round at the widest stage
                bytes: 2 * (3 * m2 * cmax + 2 * m2 * m2 * v.num_heads[v.num_stages() - 1]),
                banks: 8,
            },
            BufferSpec {
                name: "OutputBuf",
                // M² × c_o accumulation tiles, i32, double-buffered
                bytes: 2 * 4 * m2 * 32,
                banks: 2,
            },
        ];
        BufferPlan {
            buffers,
            stage_stream_windows,
        }
    }

    /// The weight buffer spec (the double-buffered stream staging area).
    pub fn weight_buffer(&self) -> &BufferSpec {
        self.buffers
            .iter()
            .find(|b| b.name == "WeightBuf")
            .expect("plan has a weight buffer")
    }

    /// One stage's in-flight weight-stream window in bytes (out-of-range
    /// stages clamp to the last stage's window).
    pub fn stream_window_bytes(&self, stage: usize) -> usize {
        match self.stage_stream_windows.get(stage) {
            Some(&w) => w,
            None => self.stage_stream_windows.last().copied().unwrap_or(0),
        }
    }

    /// Prefetch headroom of a stage: how many of its unit weight streams
    /// the weight buffer can host at once (≥ 1). This is the capacity
    /// constraint the pipeline IR's prefetch gate consults — a unit's
    /// stream may not start until the unit `depth` places ahead of it has
    /// released its slot. The last stage is double-buffered (depth 2) by
    /// construction; earlier stages have narrower windows and therefore
    /// deeper headroom.
    pub fn prefetch_depth(&self, stage: usize) -> usize {
        let window = self.stream_window_bytes(stage).max(1);
        (self.weight_buffer().bytes / window).max(1)
    }

    /// Per-stage prefetch depths (see [`Self::prefetch_depth`]).
    pub fn prefetch_depths(&self) -> Vec<usize> {
        (0..self.stage_stream_windows.len())
            .map(|s| self.prefetch_depth(s))
            .collect()
    }

    pub fn total_bytes(&self) -> usize {
        self.buffers.iter().map(|b| b.bytes).sum()
    }

    pub fn total_bram36(&self) -> usize {
        self.buffers.iter().map(|b| b.bram36()).sum()
    }

    /// Does the plan fit a device with `avail` BRAM36 blocks?
    pub fn fits(&self, avail: usize) -> bool {
        self.total_bram36() <= avail
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, MICRO, SMALL, TINY};

    #[test]
    fn bram_rounding_per_bank() {
        let b = BufferSpec {
            name: "x",
            bytes: 4609, // 1 byte over one BRAM36 (4608 B)
            banks: 1,
        };
        assert_eq!(b.bram36(), 2);
        let b2 = BufferSpec {
            name: "y",
            bytes: 4609,
            banks: 4,
        };
        // 1153 B/bank → 1 BRAM each
        assert_eq!(b2.bram36(), 4);
    }

    #[test]
    fn bram_rounding_edge_cases() {
        // a bank exactly filling one BRAM36 consumes exactly one block
        let exact = BufferSpec {
            name: "exact",
            bytes: 4608, // 36 Kb = 4608 B
            banks: 1,
        };
        assert_eq!(exact.bram36(), 1);
        let exact4 = BufferSpec {
            name: "exact4",
            bytes: 4 * 4608,
            banks: 4,
        };
        assert_eq!(exact4.bram36(), 4);
        // a zero-byte buffer consumes no BRAM regardless of banking
        for banks in [1usize, 2, 8] {
            let z = BufferSpec {
                name: "z",
                bytes: 0,
                banks,
            };
            assert_eq!(z.bram36(), 0, "banks={banks}");
        }
    }

    #[test]
    fn plans_fit_the_xczu19eg() {
        // Swin-T/S/B (and micro) must fit the XCZU19EG's 984-block budget
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let p = BufferPlan::for_variant(v);
            assert!(p.fits(984), "{}: {} BRAM", v.name, p.total_bram36());
            assert!(p.total_bram36() > 0, "{}", v.name);
        }
    }

    #[test]
    fn prefetch_depths_double_buffered_at_least() {
        // the last stage is exactly double-buffered by construction;
        // earlier stages have narrower windows, hence deeper headroom
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let p = BufferPlan::for_variant(v);
            let depths = p.prefetch_depths();
            assert_eq!(depths.len(), v.num_stages(), "{}", v.name);
            assert_eq!(*depths.last().unwrap(), 2, "{}", v.name);
            // monotone non-increasing along stages (windows widen)
            for w in depths.windows(2) {
                assert!(w[0] >= w[1], "{}: {:?}", v.name, depths);
            }
            assert!(depths[0] >= 2, "{}", v.name);
        }
        // the paper variants share the 16/8/4/2 ladder
        assert_eq!(BufferPlan::for_variant(&TINY).prefetch_depths(), vec![16, 8, 4, 2]);
    }

    /// Regression: the `for_variant` headroom feeding the pipeline IR's
    /// prefetch gate must be monotone in the variant's window and
    /// embed_dim — a wider model may never report *more* slack per stage,
    /// and growing the attention window may never shrink the weight-path
    /// headroom (it pressures the ILB/FIB, not the weight buffer).
    #[test]
    fn headroom_monotone_in_window_and_embed_dim() {
        fn variant(window: usize, embed_dim: usize) -> SwinVariant {
            SwinVariant {
                name: "probe",
                img_size: 224,
                patch_size: 4,
                in_chans: 3,
                embed_dim,
                depths: &[2, 2, 6, 2],
                num_heads: &[3, 6, 12, 24],
                window,
                mlp_ratio: 4,
                num_classes: 1000,
            }
        }
        // embed_dim ↑ → per-stage stream windows widen (non-decreasing)
        // and prefetch depths shrink (non-increasing), stage by stage
        let mut prev: Option<BufferPlan> = None;
        for dim in [48usize, 96, 192, 384] {
            let p = BufferPlan::for_variant(&variant(7, dim));
            if let Some(q) = &prev {
                for s in 0..4 {
                    assert!(p.stream_window_bytes(s) >= q.stream_window_bytes(s), "dim={dim}");
                    assert!(p.prefetch_depth(s) <= q.prefetch_depth(s), "dim={dim}");
                }
            }
            prev = Some(p);
        }
        // window ↑ → weight-path headroom unchanged, on-chip pressure
        // (ILB/FIB bytes) non-decreasing
        let mut prev: Option<BufferPlan> = None;
        for m in [7usize, 14, 28] {
            let p = BufferPlan::for_variant(&variant(m, 96));
            if let Some(q) = &prev {
                for s in 0..4 {
                    assert_eq!(p.prefetch_depth(s), q.prefetch_depth(s), "M={m}");
                }
                assert!(p.total_bytes() >= q.total_bytes(), "M={m}");
            }
            prev = Some(p);
        }
    }

    #[test]
    fn base_needs_more_bram_than_tiny() {
        // Table IV: Swin-B uses 338 BRAM vs 244 for T/S — ordering must hold
        let t = BufferPlan::for_variant(&TINY).total_bram36();
        let b = BufferPlan::for_variant(&BASE).total_bram36();
        assert!(b > t, "tiny={t} base={b}");
    }

    #[test]
    fn tiny_and_small_identical() {
        // Table IV lists identical resources for Swin-T and Swin-S
        assert_eq!(
            BufferPlan::for_variant(&TINY).total_bram36(),
            BufferPlan::for_variant(&SMALL).total_bram36()
        );
    }
}
