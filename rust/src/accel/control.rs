//! Control unit: schedules the workload graph onto the datapath
//! (paper §IV.A — three operational modes and the Swin-block dataflow).
//!
//! This module only *prices* ops and groups them into scheduling units —
//! per-op compute/nonlinear/memory cycle costs, with the paper's overlap
//! assumptions folded into the costs:
//!
//! * SCU/GCU pipeline against the MMU's next window when
//!   `overlap_nonlinear` (only their fill latency is exposed); the
//!   ablation mode serialises them fully;
//! * shortcut additions ride the MMU accumulation module (0 cycles).
//!
//! *When* each cost lands on the timeline — intra-unit double buffering,
//! cross-unit prefetch, batch replay — is decided exclusively by the
//! pipeline IR ([`super::pipeline::PipelineSchedule`]), the crate's
//! single timing source.

use crate::model::graph::{LayerOp, OpKind, WorkloadGraph};

use super::gcu::Gcu;
use super::memory::MemoryModel;
use super::mmu::Mmu;
use super::scu::Scu;
use super::AccelConfig;

/// Per-op timing decomposition.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpTiming {
    pub compute_cycles: u64,
    pub nonlinear_cycles: u64,
    /// Nonlinear cycles actually exposed on the critical path.
    pub nonlinear_exposed: u64,
    pub mem_cycles: u64,
}

/// A scheduling unit: ops that share one double-buffering boundary
/// (one transformer block, or one of the standalone modes).
#[derive(Debug, Clone)]
pub struct ScheduleUnit {
    pub label: String,
    pub stage: usize,
    pub timings: Vec<OpTiming>,
}

impl ScheduleUnit {
    pub fn compute(&self) -> u64 {
        self.timings.iter().map(|t| t.compute_cycles).sum()
    }

    pub fn nonlinear(&self) -> u64 {
        self.timings.iter().map(|t| t.nonlinear_cycles).sum()
    }

    pub fn nonlinear_exposed(&self) -> u64 {
        self.timings.iter().map(|t| t.nonlinear_exposed).sum()
    }

    pub fn mem(&self) -> u64 {
        self.timings.iter().map(|t| t.mem_cycles).sum()
    }
}

#[derive(Debug)]
pub struct Scheduler {
    pub cfg: AccelConfig,
    mmu: Mmu,
    scu: Scu,
    gcu: Gcu,
    mem: MemoryModel,
}

impl Scheduler {
    pub fn new(cfg: AccelConfig) -> Self {
        Scheduler {
            mmu: Mmu::new(cfg.clone()),
            scu: Scu::new(cfg.clone()),
            gcu: Gcu::new(cfg.clone()),
            mem: MemoryModel::new(cfg.clone()),
            cfg,
        }
    }

    fn time_op(&self, op: &LayerOp) -> OpTiming {
        let mut t = OpTiming {
            mem_cycles: self
                .mem
                .transfer_cycles((op.weight_bytes + op.activation_bytes) as u64),
            ..Default::default()
        };
        match op.op {
            OpKind::Gemm {
                batch, rows, k, n, ..
            } => {
                t.compute_cycles = self.mmu.gemm_cycles_batched(batch, rows, k, n);
            }
            OpKind::Softmax { rows, width } => {
                t.nonlinear_cycles = self.scu.softmax_cycles(rows, width);
                t.nonlinear_exposed = if self.cfg.overlap_nonlinear {
                    self.scu.softmax_exposed(rows, width)
                } else {
                    t.nonlinear_cycles
                };
            }
            OpKind::Gelu { elems } => {
                t.nonlinear_cycles = self.gcu.gelu_cycles(elems);
                t.nonlinear_exposed = if self.cfg.overlap_nonlinear {
                    self.gcu.gelu_exposed(elems)
                } else {
                    t.nonlinear_cycles
                };
            }
            OpKind::Add { .. } => {} // shortcut rides the accumulation module
        }
        t
    }

    /// Group the graph into scheduling units: each (stage, block) is one
    /// unit; standalone ops (patch embed / merge / head) get their own.
    pub fn schedule(&self, graph: &WorkloadGraph) -> Vec<ScheduleUnit> {
        let mut units: Vec<ScheduleUnit> = Vec::new();
        for op in &graph.ops {
            let label = if op.block == usize::MAX {
                format!("s{}-standalone", op.stage)
            } else {
                format!("s{}-b{}", op.stage, op.block)
            };
            match units.last_mut() {
                Some(u) if u.label == label => u.timings.push(self.time_op(op)),
                _ => units.push(ScheduleUnit {
                    label,
                    stage: op.stage,
                    timings: vec![self.time_op(op)],
                }),
            }
        }
        units
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{MICRO, TINY};

    #[test]
    fn units_cover_all_blocks() {
        let s = Scheduler::new(AccelConfig::paper());
        let g = WorkloadGraph::build(&MICRO);
        let units = s.schedule(&g);
        // micro: patchembed + 2 blocks + merge(+head rolls into the next
        // standalone unit of the same stage) + 2 blocks
        let blocks = units.iter().filter(|u| u.label.contains("-b")).count();
        assert_eq!(blocks, 4);
    }

    #[test]
    fn every_unit_carries_positive_cost() {
        let s = Scheduler::new(AccelConfig::paper());
        let g = WorkloadGraph::build(&TINY);
        for u in s.schedule(&g) {
            assert!(
                u.compute() + u.nonlinear_exposed() + u.mem() > 0,
                "{}",
                u.label
            );
        }
    }

    #[test]
    fn overlap_reduces_exposed_nonlinear() {
        use crate::accel::pipeline::PipelineSchedule;
        let g = WorkloadGraph::build(&TINY);
        let mut cfg = AccelConfig::paper();
        cfg.overlap_nonlinear = true;
        let a = PipelineSchedule::lower(&g, &Scheduler::new(cfg.clone())).total_cycles;
        cfg.overlap_nonlinear = false;
        let b = PipelineSchedule::lower(&g, &Scheduler::new(cfg)).total_cycles;
        assert!(b >= a);
    }
}
