//! Virtual accelerator device: couples the cycle model (service time) and
//! the functional model (numerics) behind a FIFO queue in *virtual time*.
//!
//! This is the scale-out substrate the paper's edge-deployment motivation
//! implies but never builds: `server::router` load-balances requests over
//! a fleet of these simulated cards, letting the multi-accelerator
//! experiments run on one CPU with faithful per-card latency. Service
//! times come from the pipeline IR ([`super::pipeline::PipelineSchedule`],
//! the crate's single timing source).

use std::sync::Arc;

use crate::model::config::SwinVariant;

use super::pipeline::PipelineSchedule;
use super::AccelConfig;

/// One simulated FPGA card.
#[derive(Debug)]
pub struct VirtualDevice {
    pub id: usize,
    pub variant: &'static SwinVariant,
    cfg: AccelConfig,
    /// The lowered event schedule this card executes — shared (`Arc`)
    /// across every card of the same variant × config in a fleet, so N
    /// homogeneous cards lower the graph once (see
    /// [`super::pipeline::CostTable`]).
    schedule: Arc<PipelineSchedule>,
    /// Virtual time (cycles) when the card becomes idle.
    busy_until: u64,
    /// Completed inferences.
    pub served: u64,
}

/// Outcome of enqueueing one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Completion {
    /// Virtual cycle the request starts executing.
    pub start: u64,
    /// Virtual cycle the result is ready.
    pub finish: u64,
    /// Queueing delay in cycles.
    pub queued: u64,
}

impl VirtualDevice {
    pub fn new(id: usize, variant: &'static SwinVariant, cfg: AccelConfig) -> Self {
        let schedule = Arc::new(PipelineSchedule::for_variant(variant, cfg));
        Self::with_schedule(id, variant, schedule)
    }

    /// Build a card over an already-lowered, shared schedule (fleet
    /// constructors lower once per variant and hand every card a clone
    /// of the `Arc`).
    pub fn with_schedule(
        id: usize,
        variant: &'static SwinVariant,
        schedule: Arc<PipelineSchedule>,
    ) -> Self {
        VirtualDevice {
            id,
            variant,
            cfg: schedule.cfg.clone(),
            schedule,
            busy_until: 0,
            served: 0,
        }
    }

    /// The card's lowered schedule (shared timing source).
    pub fn schedule(&self) -> &PipelineSchedule {
        &self.schedule
    }

    pub fn service_cycles(&self) -> u64 {
        self.schedule.total_cycles
    }

    /// Latency of one unqueued inference in milliseconds.
    pub fn service_ms(&self) -> f64 {
        self.cfg.cycles_to_ms(self.service_cycles())
    }

    /// Virtual cycle at which the card next goes idle.
    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }

    /// Queue depth in requests at virtual time `now` (ceil).
    pub fn backlog(&self, now: u64) -> u64 {
        self.busy_until
            .saturating_sub(now)
            .div_ceil(self.service_cycles().max(1))
    }

    /// Enqueue a request arriving at virtual cycle `arrival`.
    pub fn enqueue(&mut self, arrival: u64) -> Completion {
        self.enqueue_work(arrival, self.service_cycles(), 1)
    }

    /// Enqueue a work item of explicit duration (`cycles`) that completes
    /// `requests` requests at once — the batched-launch form used by
    /// [`crate::server::engine::SimEngine`].
    pub fn enqueue_work(&mut self, arrival: u64, cycles: u64, requests: u64) -> Completion {
        let start = arrival.max(self.busy_until);
        let finish = start + cycles;
        self.busy_until = finish;
        self.served += requests;
        Completion {
            start,
            finish,
            queued: start - arrival,
        }
    }

    /// Reset virtual time (new experiment).
    pub fn reset(&mut self) {
        self.busy_until = 0;
        self.served = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::TINY;

    fn dev() -> VirtualDevice {
        VirtualDevice::new(0, &TINY, AccelConfig::paper())
    }

    #[test]
    fn service_time_matches_simulator_fps() {
        let d = dev();
        let fps = 1000.0 / d.service_ms();
        assert!((38.0..45.0).contains(&fps), "fps={fps}");
    }

    #[test]
    fn service_cycles_come_from_the_pipeline_schedule() {
        use crate::accel::sim::Simulator;
        let d = dev();
        let r = Simulator::new(&TINY, AccelConfig::paper()).simulate_inference();
        assert_eq!(d.service_cycles(), r.total_cycles);
        assert_eq!(d.schedule().launch_cycles(1), d.service_cycles());
    }

    #[test]
    fn back_to_back_requests_queue() {
        let mut d = dev();
        let c1 = d.enqueue(0);
        let c2 = d.enqueue(0);
        assert_eq!(c1.queued, 0);
        assert_eq!(c2.start, c1.finish);
        assert_eq!(c2.queued, c1.finish);
        assert_eq!(d.served, 2);
    }

    #[test]
    fn idle_gap_resets_queueing() {
        let mut d = dev();
        let c1 = d.enqueue(0);
        let late = c1.finish + 1000;
        let c2 = d.enqueue(late);
        assert_eq!(c2.queued, 0);
        assert_eq!(c2.start, late);
    }

    #[test]
    fn backlog_counts_pending() {
        let mut d = dev();
        for _ in 0..3 {
            d.enqueue(0);
        }
        assert_eq!(d.backlog(0), 3);
        assert_eq!(d.backlog(d.busy_until()), 0);
    }

    #[test]
    fn reset_clears_state() {
        let mut d = dev();
        d.enqueue(0);
        d.reset();
        assert_eq!(d.busy_until(), 0);
        assert_eq!(d.served, 0);
    }
}
