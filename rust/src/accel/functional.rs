//! Functional whole-model simulation: the accelerator's 16-bit datapath
//! end-to-end, bit-identical to `model.forward_fixed` (the AOT'd
//! fixed-point artifact). Verified against PJRT execution of that
//! artifact in `rust/tests/cross_check.rs`.
//!
//! Every arithmetic step mirrors the jnp implementation exactly:
//! round-half-even input quantisation, Q3.12 weights with `>>12`
//! round-half-up requantisation, post-requant Q7.8 bias with saturation,
//! SCU/GCU golden models, saturating shortcut adds, fixed-point GAP.

use anyhow::Result;

use crate::fixed::{fixed_mean, quantize, sat16, DATA_FRAC, PROB_FRAC};
use crate::model::config::SwinVariant;
use crate::model::weights::WeightStore;

use super::gcu::Gcu;
use super::mmu::Mmu;
use super::scu::Scu;
use super::tiling::{patch_embed_tokens, FeatureMap, IntMat};
use super::AccelConfig;

/// Additive attention-mask fill, quantised: round(-100.0 · 2⁸).
pub const MASK_FILL_Q: i32 = -25_600;

/// Standard Swin relative-position index table: (m² × m²) entries into
/// the (2m−1)² bias table. Mirrors `model.relative_position_index`.
pub fn relative_position_index(m: usize) -> Vec<Vec<usize>> {
    let n = m * m;
    let mut idx = vec![vec![0usize; n]; n];
    for i in 0..n {
        let (yi, xi) = (i / m, i % m);
        for j in 0..n {
            let (yj, xj) = (j / m, j % m);
            let dy = yi as isize - yj as isize + (m as isize - 1);
            let dx = xi as isize - xj as isize + (m as isize - 1);
            idx[i][j] = (dy * (2 * m as isize - 1) + dx) as usize;
        }
    }
    idx
}

/// SW-MSA window masks: per-window (m² × m²) additive masks in Q7.8
/// (`MASK_FILL_Q` or 0); `None` when shift == 0. Mirrors
/// `model.shift_attn_mask`.
pub fn shift_attn_mask(h: usize, w: usize, m: usize, shift: usize) -> Option<Vec<IntMat>> {
    if shift == 0 {
        return None;
    }
    let mut img = vec![vec![0i32; w]; h];
    let mut cnt = 0;
    let spans = |len: usize| vec![(0, len - m), (len - m, len - shift), (len - shift, len)];
    for (y0, y1) in spans(h) {
        for (x0, x1) in spans(w) {
            for row in img.iter_mut().take(y1).skip(y0) {
                for v in row.iter_mut().take(x1).skip(x0) {
                    *v = cnt;
                }
            }
            cnt += 1;
        }
    }
    let gw = w / m;
    let gh = h / m;
    let mut masks = Vec::with_capacity(gh * gw);
    for wy in 0..gh {
        for wx in 0..gw {
            let mut win = Vec::with_capacity(m * m);
            for iy in 0..m {
                for ix in 0..m {
                    win.push(img[wy * m + iy][wx * m + ix]);
                }
            }
            let mut mat = IntMat::zeros(m * m, m * m);
            for i in 0..m * m {
                for j in 0..m * m {
                    if win[i] != win[j] {
                        mat.set(i, j, MASK_FILL_Q);
                    }
                }
            }
            masks.push(mat);
        }
    }
    Some(masks)
}

/// The functional accelerator: fused quantised weights + compute units.
pub struct FunctionalModel<'a> {
    pub variant: &'static SwinVariant,
    weights: &'a WeightStore,
    mmu: Mmu,
    scu: Scu,
    gcu: Gcu,
}

impl<'a> FunctionalModel<'a> {
    pub fn new(
        variant: &'static SwinVariant,
        weights: &'a WeightStore,
        cfg: AccelConfig,
    ) -> Self {
        FunctionalModel {
            variant,
            weights,
            mmu: Mmu::new(cfg.clone()),
            scu: Scu::new(cfg.clone()),
            gcu: Gcu::new(cfg),
        }
    }

    fn w(&self, name: &str) -> Result<IntMat> {
        let t = self.weights.matrix(name)?;
        Ok(IntMat::from_vec(t.shape[0], t.shape[1], t.data.clone()))
    }

    fn b(&self, name: &str) -> Result<Vec<i32>> {
        Ok(self.weights.vector(name)?.data.clone())
    }

    /// `_linear_fixed`: x(Q7.8) @ w(Q3.12) >> 12, + bias(Q7.8), saturate.
    fn linear(&self, x: &IntMat, wname: &str, bname: &str) -> Result<IntMat> {
        let w = self.w(wname)?;
        let bias = self.b(bname)?;
        Ok(self
            .mmu
            .gemm_bias(x, &w, &bias, self.weights.weight_frac))
    }

    /// Quantise an f32 image (H·W·3, row-major, value range ~[0,1]).
    pub fn quantize_image(&self, img: &[f32]) -> FeatureMap {
        let v = self.variant;
        assert_eq!(img.len(), v.img_size * v.img_size * v.in_chans);
        let mut fm = FeatureMap::zeros(v.img_size, v.img_size, v.in_chans);
        for (dst, &src) in fm.data.iter_mut().zip(img) {
            *dst = quantize(src, DATA_FRAC);
        }
        fm
    }

    /// Full forward pass: image → class logits (Q7.8).
    pub fn run_image(&self, img: &[f32]) -> Result<Vec<i32>> {
        let v = self.variant;
        let m = v.window;
        let x = self.quantize_image(img);

        // Patch embedding (mode 1): im2col + MMU
        let tokens = patch_embed_tokens(&x, v.patch_size);
        let emb = self.linear(&tokens, "patch_embed.wq", "patch_embed.bq")?;
        let hp = v.img_size / v.patch_size;
        let mut fm = FeatureMap::from_tokens(&emb, hp, hp);

        // Stages of Swin blocks (mode 3) + patch merging (mode 2)
        for s in 0..v.num_stages() {
            let res = v.stage_resolution(s);
            let nh = v.num_heads[s];
            for blk in 0..v.depths[s] {
                let shift = if blk % 2 == 0 || res <= m { 0 } else { m / 2 };
                fm = self.swin_block(fm, s, blk, nh, shift)?;
            }
            if s + 1 < v.num_stages() {
                let merged = fm.merge_2x2();
                let t = merged.to_tokens();
                let out = self.linear(
                    &t,
                    &format!("stages.{s}.merge.wq"),
                    &format!("stages.{s}.merge.bq"),
                )?;
                fm = FeatureMap::from_tokens(&out, res / 2, res / 2);
            }
        }

        // GAP (fixed mean) + classifier head
        let t = fm.to_tokens();
        let ntok = t.rows;
        let df = t.cols;
        let mut pooled = IntMat::zeros(1, df);
        for c in 0..df {
            let mut sum = 0i32;
            for r in 0..ntok {
                sum = sum.wrapping_add(t.at(r, c));
            }
            pooled.set(0, c, fixed_mean(sum, ntok));
        }
        let logits = self.linear(&pooled, "head.wq", "head.bq")?;
        Ok(logits.row(0).to_vec())
    }

    fn swin_block(
        &self,
        fm: FeatureMap,
        s: usize,
        blk: usize,
        nh: usize,
        shift: usize,
    ) -> Result<FeatureMap> {
        let v = self.variant;
        let m = v.window;
        let m2 = m * m;
        let res = fm.h;
        let c = fm.c;
        let dh = c / nh;
        let p = format!("stages.{s}.blocks.{blk}");

        let shortcut = fm.clone();
        let rolled = if shift > 0 {
            fm.roll(-(shift as isize), -(shift as isize))
        } else {
            fm
        };
        let wins = rolled.window_partition(m);
        let nw = wins.len();

        // QKV over all windowed tokens at once (python reshapes b_*n rows)
        let mut all = IntMat::zeros(nw * m2, c);
        for (wi, win) in wins.iter().enumerate() {
            for r in 0..m2 {
                for ch in 0..c {
                    all.set(wi * m2 + r, ch, win.at(r, ch));
                }
            }
        }
        let qkv = self.linear(&all, &format!("{p}.attn.wqkv"), &format!("{p}.attn.bqkv"))?;

        let rel = self.weights.matrix(&format!("{p}.attn.rel_bias_q"))?;
        let rel_idx = relative_position_index(m);
        let masks = shift_attn_mask(res, res, m, shift);

        // Per window, per head: scores → +bias/mask → softmax → ·V
        let mut attn_out = IntMat::zeros(nw * m2, c);
        for wi in 0..nw {
            for h in 0..nh {
                let col = |qkv_idx: usize, d: usize| qkv_idx * c + h * dh + d;
                let mut q = IntMat::zeros(m2, dh);
                let mut kt = IntMat::zeros(dh, m2);
                let mut vv = IntMat::zeros(m2, dh);
                for r in 0..m2 {
                    for d in 0..dh {
                        q.set(r, d, qkv.at(wi * m2 + r, col(0, d)));
                        kt.set(d, r, qkv.at(wi * m2 + r, col(1, d)));
                        vv.set(r, d, qkv.at(wi * m2 + r, col(2, d)));
                    }
                }
                // Q·Kᵀ (the padded-Kᵀ GEMM), requantise >> 8
                let mut scores = self.mmu.gemm(&q, &kt, DATA_FRAC);
                for i in 0..m2 {
                    for j in 0..m2 {
                        let mut v2 = sat16(scores.at(i, j) + rel.data[rel_idx[i][j] * nh + h]);
                        if let Some(ms) = &masks {
                            v2 = sat16(v2 + ms[wi].at(i, j));
                        }
                        scores.set(i, j, v2);
                    }
                }
                // SCU
                let probs = self.scu.softmax(&scores.data, m2);
                let probs = IntMat::from_vec(m2, m2, probs);
                // probs(Q0.15) · V(Q7.8) → >> 15
                let out = self.mmu.gemm(&probs, &vv, PROB_FRAC);
                for r in 0..m2 {
                    for d in 0..dh {
                        attn_out.set(wi * m2 + r, h * dh + d, out.at(r, d));
                    }
                }
            }
        }

        // projection, un-window, un-shift, shortcut
        let proj = self.linear(
            &attn_out,
            &format!("{p}.attn.wproj"),
            &format!("{p}.attn.bproj"),
        )?;
        let proj_wins: Vec<IntMat> = (0..nw)
            .map(|wi| {
                let mut w2 = IntMat::zeros(m2, c);
                for r in 0..m2 {
                    for ch in 0..c {
                        w2.set(r, ch, proj.at(wi * m2 + r, ch));
                    }
                }
                w2
            })
            .collect();
        let mut back = FeatureMap::window_reverse(&proj_wins, m, res, res);
        if shift > 0 {
            back = back.roll(shift as isize, shift as isize);
        }
        let mut x1 = FeatureMap::zeros(res, res, c);
        for i in 0..x1.data.len() {
            x1.data[i] = sat16(shortcut.data[i] + back.data[i]);
        }

        // FFN: mlp1 → GCU → mlp2, shortcut
        let t = x1.to_tokens();
        let h1 = self.linear(&t, &format!("{p}.mlp.w1q"), &format!("{p}.mlp.b1q"))?;
        let g = IntMat::from_vec(h1.rows, h1.cols, self.gcu.gelu(&h1.data));
        let h2 = self.linear(&g, &format!("{p}.mlp.w2q"), &format!("{p}.mlp.b2q"))?;
        let mut out = FeatureMap::zeros(res, res, c);
        for i in 0..out.data.len() {
            out.data[i] = sat16(x1.data[i] + h2.data[i]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_index_matches_python_properties() {
        let idx = relative_position_index(7);
        assert_eq!(idx.len(), 49);
        let diag = idx[0][0];
        for i in 0..49 {
            assert_eq!(idx[i][i], diag);
        }
        let flat: Vec<usize> = idx.iter().flatten().copied().collect();
        assert!(flat.iter().all(|&v| v < 169));
        // symmetry: idx[i][j] and idx[j][i] mirror through the centre
        let centre = (13 * 13 - 1) / 2;
        for i in 0..49 {
            for j in 0..49 {
                assert_eq!(idx[i][j] + idx[j][i], 2 * centre);
            }
        }
    }

    #[test]
    fn mask_structure_mirrors_python() {
        let masks = shift_attn_mask(14, 14, 7, 3).unwrap();
        assert_eq!(masks.len(), 4);
        // window 0 unmasked
        assert!(masks[0].data.iter().all(|&v| v == 0));
        // cut windows are symmetric with some masked pairs
        assert!(masks[1].data.iter().any(|&v| v == MASK_FILL_Q));
        for i in 0..49 {
            for j in 0..49 {
                assert_eq!(masks[1].at(i, j), masks[1].at(j, i));
            }
        }
        assert!(shift_attn_mask(14, 14, 7, 0).is_none());
    }
}
