//! GCU — GELU Compute Unit (paper §IV.D, Fig. 10).
//!
//! Functional model delegates to [`crate::approx::gelu`]; the cycle model
//! is a lanes-wide pipeline (Table III's 98 DSP = 2 EUs × 49 lanes):
//! `⌈elems / lanes⌉ + depth` cycles.

use crate::approx::gelu::gelu_slice;

use super::AccelConfig;

#[derive(Debug, Clone)]
pub struct Gcu {
    cfg: AccelConfig,
}

impl Gcu {
    pub fn new(cfg: AccelConfig) -> Self {
        Gcu { cfg }
    }

    /// Functional GELU over a tensor slice (Q7.8 → Q7.8).
    pub fn gelu(&self, xs: &[i32]) -> Vec<i32> {
        gelu_slice(xs, false)
    }

    /// Ablation: the 12-bit corrected cubic constant (DESIGN.md §6).
    pub fn gelu_corrected(&self, xs: &[i32]) -> Vec<i32> {
        gelu_slice(xs, true)
    }

    /// Cycle cost for `elems` activations.
    pub fn gelu_cycles(&self, elems: usize) -> u64 {
        elems.div_ceil(self.cfg.gcu_lanes) as u64 + self.cfg.gcu_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_scale_linearly() {
        let g = Gcu::new(AccelConfig::paper());
        assert_eq!(g.gelu_cycles(49), 1 + 18);
        assert_eq!(g.gelu_cycles(490), 10 + 18);
        assert_eq!(g.gelu_cycles(0), 18);
    }

    #[test]
    fn functional_matches_golden() {
        let g = Gcu::new(AccelConfig::paper());
        let xs: Vec<i32> = (-20..20).map(|i| i * 51).collect();
        assert_eq!(g.gelu(&xs), crate::approx::gelu::gelu_slice(&xs, false));
        assert_eq!(
            g.gelu_corrected(&xs),
            crate::approx::gelu::gelu_slice(&xs, true)
        );
    }
}
