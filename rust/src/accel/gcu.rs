//! GCU — GELU Compute Unit (paper §IV.D, Fig. 10).
//!
//! Numerics and cycle cost are design-backed
//! ([`AccelConfig::nl_design`] → [`super::nonlinear::NonlinearDesign`]).
//! The paper's circuit is the baseline: a lanes-wide pipeline (Table
//! III's 98 DSP = 2 EUs × 49 lanes) costing `⌈elems / lanes⌉ + depth`
//! cycles; QUARK sharing serialises tiles at II = 2, PEANO shortens the
//! pipe by replacing the log-domain division with a shift-add reciprocal.

use crate::approx::gelu::gelu_slice;

use super::AccelConfig;

#[derive(Debug, Clone)]
pub struct Gcu {
    cfg: AccelConfig,
}

impl Gcu {
    pub fn new(cfg: AccelConfig) -> Self {
        Gcu { cfg }
    }

    /// Functional GELU over a tensor slice (Q7.8 → Q7.8), through the
    /// configured design's kernel.
    pub fn gelu(&self, xs: &[i32]) -> Vec<i32> {
        self.cfg.nl_design.design().gelu(xs)
    }

    /// Ablation: the baseline circuit with the 12-bit corrected cubic
    /// constant (DESIGN.md §6). Always the paper's datapath regardless
    /// of the configured design — it isolates the constant, not the
    /// divider.
    pub fn gelu_corrected(&self, xs: &[i32]) -> Vec<i32> {
        gelu_slice(xs, true)
    }

    /// Cycle cost for `elems` activations under the configured design.
    pub fn gelu_cycles(&self, elems: usize) -> u64 {
        self.cfg.nl_design.design().gelu_cycles(&self.cfg, elems)
    }

    /// Cycles exposed on the critical path when GELU overlaps the MMU's
    /// next window (`overlap_nonlinear`).
    pub fn gelu_exposed(&self, elems: usize) -> u64 {
        self.cfg.nl_design.design().gelu_exposed(&self.cfg, elems)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycles_scale_linearly() {
        let g = Gcu::new(AccelConfig::paper());
        assert_eq!(g.gelu_cycles(49), 1 + 18);
        assert_eq!(g.gelu_cycles(490), 10 + 18);
        assert_eq!(g.gelu_cycles(0), 18);
    }

    #[test]
    fn functional_matches_golden() {
        let g = Gcu::new(AccelConfig::paper());
        let xs: Vec<i32> = (-20..20).map(|i| i * 51).collect();
        assert_eq!(g.gelu(&xs), crate::approx::gelu::gelu_slice(&xs, false));
        assert_eq!(
            g.gelu_corrected(&xs),
            crate::approx::gelu::gelu_slice(&xs, true)
        );
    }

    #[test]
    fn design_dispatch_switches_numerics_and_cycles() {
        use crate::accel::nonlinear::NlDesign;
        let base = Gcu::new(AccelConfig::paper());
        let p = Gcu::new(AccelConfig::paper().nonlinear(NlDesign::Peano));
        let xs: Vec<i32> = (-20..20).map(|i| i * 51).collect();
        assert_eq!(p.gelu(&xs), crate::approx::peano::gelu_slice_peano(&xs));
        assert!(p.gelu_cycles(490) < base.gelu_cycles(490));
        let q = Gcu::new(AccelConfig::paper().nonlinear(NlDesign::Quark));
        assert_eq!(q.gelu(&xs), base.gelu(&xs));
        assert!(q.gelu_cycles(490) > base.gelu_cycles(490));
    }
}
