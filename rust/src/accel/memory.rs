//! External memory model — MRU/MWU over the AXI External Memory Interface
//! (paper Fig. 3).
//!
//! Weights are streamed from external DDR every frame (they do not fit
//! on-chip: Swin-T alone is 56 MB at int16); activations spill between
//! blocks. Transfers are modelled as `bytes / (bus_width × efficiency)`
//! cycles, with MRU (reads) and MWU (writes) sharing the interface.

use super::AccelConfig;

#[derive(Debug, Clone)]
pub struct MemoryModel {
    cfg: AccelConfig,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    pub weight_bytes: u64,
    pub read_bytes: u64,
    pub write_bytes: u64,
}

impl Traffic {
    pub fn total(&self) -> u64 {
        self.weight_bytes + self.read_bytes + self.write_bytes
    }
}

impl MemoryModel {
    pub fn new(cfg: AccelConfig) -> Self {
        MemoryModel { cfg }
    }

    /// Cycles to move `bytes` across the interface.
    pub fn transfer_cycles(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.cfg.effective_bw()).ceil() as u64
    }

    /// Effective bandwidth in GB/s at the configured clock.
    pub fn bandwidth_gbps(&self) -> f64 {
        self.cfg.effective_bw() * self.cfg.freq_mhz * 1e6 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bandwidth_order_of_magnitude() {
        // 128-bit AXI @ 200 MHz, 88% efficient → ~2.8 GB/s
        let m = MemoryModel::new(AccelConfig::paper());
        let bw = m.bandwidth_gbps();
        assert!(bw > 2.0 && bw < 3.5, "bw={bw}");
    }

    #[test]
    fn transfer_cycles_rounding() {
        let m = MemoryModel::new(AccelConfig::paper());
        assert_eq!(m.transfer_cycles(0), 0);
        // 14.08 effective bytes/cycle → 1 cycle for 1 byte
        assert_eq!(m.transfer_cycles(1), 1);
        let c = m.transfer_cycles(56_600_000);
        // Swin-T weights at 15.2 B/cycle: ~3.7M cycles ≈ 18.6 ms @ 200 MHz
        assert!((3_600_000..3_850_000).contains(&c), "c={c}");
    }

    #[test]
    fn traffic_sums() {
        let t = Traffic {
            weight_bytes: 10,
            read_bytes: 20,
            write_bytes: 30,
        };
        assert_eq!(t.total(), 60);
    }
}
