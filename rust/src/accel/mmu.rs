//! MMU — Matrix Multiplication Unit (paper §IV.B, Fig. 4).
//!
//! 32 PEs × 49 multipliers = 1568 DSP48E1. Each PE multiplies the shared
//! (M² × c_i) A-tile against one c_i×1 column of the B-tile, one column
//! element per cycle, accumulating into the Accumulation module over
//! C_I/c_i passes; write-back requantises to Q7.8.
//!
//! * functional model: [`Mmu::gemm`] — wrap-around i32 accumulation,
//!   round-half-up requantisation, identical to the Pallas `mmu.py`
//!   kernel (cross-checked bit-for-bit in `rust/tests/cross_check.rs`);
//! * cycle model: [`Mmu::gemm_cycles`] — `⌈rows/M²⌉·⌈n/c_o⌉·k_pad`
//!   compute cycles plus pipeline fill per output tile.

use crate::fixed::{requantize_acc, sat16};
use crate::model::graph::TILE_M;

use super::tiling::{pad_up, IntMat};
use super::AccelConfig;

/// The MMU: functional + cycle models. Stateless; geometry from config.
#[derive(Debug, Clone)]
pub struct Mmu {
    cfg: AccelConfig,
}

impl Mmu {
    pub fn new(cfg: AccelConfig) -> Self {
        Mmu { cfg }
    }

    /// Functional blocked GEMM: `(rows×k) @ (k×n)` with internal
    /// zero-padding to tile alignment, i32 wrap accumulation, and
    /// `>> rshift` round-half-up write-back (saturating to i16 range).
    ///
    /// The result is cropped back to the logical (rows × n) — the padded
    /// region is the §V.A "invalid computation".
    pub fn gemm(&self, a: &IntMat, b: &IntMat, rshift: u32) -> IntMat {
        assert_eq!(a.cols, b.rows, "GEMM shape mismatch");
        let mut out = IntMat::zeros(a.rows, b.cols);
        // Zero-padding contributes exact zeros to the accumulation, so the
        // functional result equals plain integer GEMM on the logical
        // shapes. (The pallas kernel computes the padded form; outputs in
        // the cropped region are bit-identical.)
        //
        // Loop order is k-inner-over-rows-of-B (output-row accumulators):
        // B is walked row-major (sequential, vectorisable) instead of
        // column-wise — §Perf iteration 1, −60 % on the hot shapes.
        // Wrapping i32 adds commute, so the result is bit-identical to the
        // naive order (asserted by the cross-check suite).
        let n = b.cols;
        let mut acc = vec![0i32; n];
        for r in 0..a.rows {
            acc.fill(0);
            let arow = a.row(r);
            for (i, &av) in arow.iter().enumerate() {
                if av == 0 {
                    continue; // padded region / sparse rows
                }
                let brow = b.row(i);
                for (dst, &bv) in acc.iter_mut().zip(brow) {
                    *dst = dst.wrapping_add(av.wrapping_mul(bv));
                }
            }
            let orow = &mut out.data[r * n..(r + 1) * n];
            for (o, &v) in orow.iter_mut().zip(&acc) {
                *o = requantize_acc(v, rshift);
            }
        }
        // (§Perf iteration 2 — two-row blocking to halve B traffic — was
        // tried and REVERTED: it broke the inner loop's vectorisation and
        // cost -23 % aggregate; see EXPERIMENTS.md §Perf.)
        out
    }

    /// GEMM + bias (bias added post-requantisation in Q7.8, saturating —
    /// matching `model._linear_fixed`).
    pub fn gemm_bias(&self, a: &IntMat, b: &IntMat, bias: &[i32], rshift: u32) -> IntMat {
        assert_eq!(bias.len(), b.cols);
        let mut out = self.gemm(a, b, rshift);
        for r in 0..out.rows {
            for c in 0..out.cols {
                out.set(r, c, sat16(out.at(r, c) + bias[c]));
            }
        }
        out
    }

    /// Compute cycles for one logical GEMM (paper Fig. 4 schedule):
    /// every (M² × c_o) output tile needs k_pad cycles of accumulation
    /// (one B-column element per PE per cycle) plus pipeline fill.
    pub fn gemm_cycles(&self, rows: usize, k: usize, n: usize) -> u64 {
        // Degenerate GEMMs move no data through the array: no tile is ever
        // issued, so no pipeline fill is paid either.
        if rows == 0 || k == 0 || n == 0 {
            return 0;
        }
        let row_tiles = pad_up(rows, TILE_M) / TILE_M;
        let n_tiles = pad_up(n, self.cfg.tile_n) / self.cfg.tile_n;
        let k_pad = pad_up(k, self.cfg.tile_k) as u64;
        (row_tiles * n_tiles) as u64 * (k_pad + self.cfg.mmu_fill)
    }

    /// Cycles for a batch of identical GEMMs (windows × heads).
    pub fn gemm_cycles_batched(&self, batch: usize, rows: usize, k: usize, n: usize) -> u64 {
        batch as u64 * self.gemm_cycles(rows, k, n)
    }

    /// Peak MAC throughput sanity value.
    pub fn macs_per_cycle(&self) -> u64 {
        self.cfg.mmu_macs_per_cycle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::graph::{TILE_K, TILE_N};
    use crate::util::prng::Rng;

    fn mmu() -> Mmu {
        Mmu::new(AccelConfig::paper())
    }

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize, lim: i32) -> IntMat {
        IntMat::from_vec(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.range_i32(-lim, lim)).collect(),
        )
    }

    #[test]
    fn gemm_matches_reference_integer_matmul() {
        let mut rng = Rng::new(1);
        let a = rand_mat(&mut rng, 49, 96, 2000);
        let b = rand_mat(&mut rng, 96, 64, 2000);
        let out = mmu().gemm(&a, &b, 8);
        for r in [0usize, 13, 48] {
            for c in [0usize, 31, 63] {
                let mut acc: i64 = 0;
                for i in 0..96 {
                    acc += a.at(r, i) as i64 * b.at(i, c) as i64;
                }
                let want = crate::fixed::requantize_acc(acc as i32, 8);
                assert_eq!(out.at(r, c), want);
            }
        }
    }

    #[test]
    fn bias_applied_with_saturation() {
        let a = IntMat::from_vec(1, 1, vec![256]); // 1.0
        let b = IntMat::from_vec(1, 1, vec![1 << 12]); // 1.0 Q12
        let out = mmu().gemm_bias(&a, &b, &[32_700], 12);
        assert_eq!(out.at(0, 0), crate::fixed::I16_MAX); // saturated
    }

    #[test]
    fn property_zero_padding_never_changes_output() {
        // randomized property check (substitute for proptest, see util)
        let mut rng = Rng::new(7);
        for _ in 0..25 {
            let rows = 1 + rng.below(60) as usize;
            let k = 1 + rng.below(70) as usize;
            let n = 1 + rng.below(70) as usize;
            let a = rand_mat(&mut rng, rows, k, 500);
            let b = rand_mat(&mut rng, k, n, 500);
            let direct = mmu().gemm(&a, &b, 12);
            let ap = a.pad_to(pad_up(rows, TILE_M), pad_up(k, TILE_K));
            let bp = b.pad_to(pad_up(k, TILE_K), pad_up(n, TILE_N));
            let padded = mmu().gemm(&ap, &bp, 12).crop(rows, n);
            assert_eq!(direct, padded, "rows={rows} k={k} n={n}");
        }
    }

    #[test]
    fn bias_saturates_at_both_rails() {
        let m = mmu();
        // +: 1.0 (Q7.8) × 1.0 (Q3.12) = 256 post-requant; bias pushes past
        // I16_MAX and must pin there
        let a = IntMat::from_vec(1, 1, vec![256]);
        let b = IntMat::from_vec(1, 1, vec![1 << 12]);
        assert_eq!(
            m.gemm_bias(&a, &b, &[32_700], 12).at(0, 0),
            crate::fixed::I16_MAX
        );
        // -: mirrored product with a deeply negative bias pins at I16_MIN
        let an = IntMat::from_vec(1, 1, vec![-256]);
        assert_eq!(
            m.gemm_bias(&an, &b, &[-32_700], 12).at(0, 0),
            crate::fixed::I16_MIN
        );
        // exact rails: sums landing exactly on the rail are NOT clipped
        assert_eq!(
            m.gemm_bias(&a, &b, &[crate::fixed::I16_MAX - 256], 12).at(0, 0),
            crate::fixed::I16_MAX
        );
        assert_eq!(
            m.gemm_bias(&an, &b, &[crate::fixed::I16_MIN + 256], 12).at(0, 0),
            crate::fixed::I16_MIN
        );
    }

    #[test]
    fn gemm_non_tile_multiple_matches_i64_reference() {
        // 50×33 @ 33×65: every dimension off the 49/32/32 tile grid
        let (rows, k, n) = (50usize, 33usize, 65usize);
        let mut rng = Rng::new(99);
        let a = rand_mat(&mut rng, rows, k, 2000);
        let b = rand_mat(&mut rng, k, n, 2000);
        let out = mmu().gemm(&a, &b, 8);
        assert_eq!((out.rows, out.cols), (rows, n));
        for r in 0..rows {
            for c in 0..n {
                let mut acc: i64 = 0;
                for i in 0..k {
                    acc += a.at(r, i) as i64 * b.at(i, c) as i64;
                }
                // the datapath accumulates in wrapping i32: reduce the i64
                // reference the same way before requantising
                let want = crate::fixed::requantize_acc(acc as i32, 8);
                assert_eq!(out.at(r, c), want, "({r},{c})");
            }
        }
    }

    #[test]
    fn cycle_model_degenerate_shapes_cost_zero() {
        let m = mmu();
        for (rows, k, n) in [(0, 32, 32), (49, 0, 32), (49, 32, 0), (0, 0, 0)] {
            assert_eq!(m.gemm_cycles(rows, k, n), 0, "{rows}x{k}x{n}");
        }
        // ... and any non-degenerate shape pays at least one fill
        assert!(m.gemm_cycles(1, 1, 1) > 0);
        assert_eq!(m.gemm_cycles_batched(0, 49, 32, 32), 0);
    }

    #[test]
    fn cycle_model_tile_counts() {
        let m = mmu();
        // one 49×32 tile, k=32: 32 accumulation cycles + 8 fill
        assert_eq!(m.gemm_cycles(49, 32, 32), 40);
        // scores GEMM: 49×32 @ 32×49 → n pads to 64 → 2 tiles
        assert_eq!(m.gemm_cycles(49, 32, 49), 2 * 40);
        // rows pad: 50 rows → 2 row-tiles
        assert_eq!(m.gemm_cycles(50, 32, 32), 2 * 40);
    }

    #[test]
    fn cycle_model_matches_mac_throughput_asymptotically() {
        let m = mmu();
        // large aligned GEMM: cycles ≈ MACs / 1568
        let (rows, k, n) = (49 * 8, 256, 256);
        let cycles = m.gemm_cycles(rows, k, n);
        let macs = (rows * k * n) as u64;
        let ideal = macs / m.macs_per_cycle();
        let ratio = cycles as f64 / ideal as f64;
        assert!(ratio > 0.99 && ratio < 1.30, "ratio={ratio}");
    }

    #[test]
    fn batched_is_linear() {
        let m = mmu();
        assert_eq!(
            m.gemm_cycles_batched(12, 49, 32, 49),
            12 * m.gemm_cycles(49, 32, 49)
        );
    }
}
