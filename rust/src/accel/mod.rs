//! The FPGA accelerator, simulated.
//!
//! The paper's hardware (Fig. 3) is reproduced as two coupled models:
//!
//! * a **functional model** — bit-exact 16-bit fixed-point datapath
//!   ([`mmu`], [`crate::approx`], [`functional`]) that computes the same
//!   numbers the RTL would; verified bit-for-bit against the AOT'd Pallas
//!   kernels via PJRT in `rust/tests/cross_check.rs`;
//! * a **cycle model** — per-unit timing ([`mmu`], [`scu`], [`gcu`]),
//!   BRAM buffer capacity ([`buffers`]), external-memory bandwidth
//!   ([`memory`]) and the control unit's schedule ([`control`], [`sim`])
//!   that together produce the FPS/GOPS numbers of Table V.
//!
//! Resource (Table III/IV) and power (Table V / Fig. 12) models live in
//! [`resources`] and [`power`].

pub mod buffers;
pub mod control;
pub mod device;
pub mod functional;
pub mod gcu;
pub mod memory;
pub mod mmu;
pub mod nonlinear;
pub mod pipeline;
pub mod power;
pub mod resources;
pub mod scu;
pub mod shard;
pub mod sim;
pub mod tiling;
pub mod trace;

/// Global accelerator configuration (the paper's deployment point plus
/// the knobs the `design_space` example sweeps).
#[derive(Debug, Clone)]
pub struct AccelConfig {
    /// Clock frequency (paper: 200 MHz on XCZU19EG).
    pub freq_mhz: f64,
    /// MMU geometry: PEs × multipliers/PE (paper: 32 × 49 = 1568 DSP).
    pub mmu_pes: usize,
    pub mmu_mults_per_pe: usize,
    /// MMU output-tile width c_o (paper: 32 = head dim).
    pub tile_n: usize,
    /// MMU reduction-tile depth c_i (paper: 32).
    pub tile_k: usize,
    /// External memory bus width in bytes/cycle (128-bit AXI = 16 B).
    pub axi_bytes_per_cycle: usize,
    /// Achievable fraction of peak bandwidth (DDR efficiency).
    pub mem_efficiency: f64,
    /// Pipeline fill cycles per MMU output tile (adder tree depth +
    /// requantisation + write-back).
    pub mmu_fill: u64,
    /// SCU lanes (paper: parallelism = row width = 49) and pipe depth.
    pub scu_lanes: usize,
    pub scu_depth: u64,
    /// GCU lanes (2 EUs × 49, Table III: 98 DSP) and pipe depth.
    pub gcu_lanes: usize,
    pub gcu_depth: u64,
    /// Whether SCU/GCU execution overlaps the MMU (the paper pipelines
    /// nonlinear units against the next window's GEMM; ablatable).
    pub overlap_nonlinear: bool,
    /// Whether the MRU prefetches the *next* scheduling unit's weights
    /// while the current unit computes (cross-unit double buffering, the
    /// prefetch structure of Lu et al. / ViTA). `false` reproduces the
    /// strictly sequential per-unit numbers the Table V calibration was
    /// done under; see [`pipeline`].
    pub overlap_interunit: bool,
    /// Whether the MRU streams launch *N+1*'s weight tiles while launch
    /// *N* still computes (cross-launch prefetch in a back-to-back launch
    /// queue — the ViTA cross-iteration structure). Gates the
    /// launch-sequence IR ([`pipeline::SequenceSchedule`]): `false` makes
    /// a sequence cost exactly `Σ launch_cycles(bᵢ)`, `true` gives warm
    /// steady-state launches that skip the cold entry fill and start
    /// compute the moment the MMU frees. Per-launch costs of a *single*
    /// launch are unaffected.
    pub overlap_interlaunch: bool,
    /// Which nonlinear-unit design the SCU/GCU implement — numerics,
    /// cycle cost and resource/power footprint switch together (see
    /// [`nonlinear`]). The paper's circuits are
    /// [`nonlinear::NlDesign::Baseline`].
    pub nl_design: nonlinear::NlDesign,
}

impl AccelConfig {
    /// The paper's accelerator as deployed on the XCZU19EG.
    pub fn paper() -> Self {
        AccelConfig {
            freq_mhz: 200.0,
            mmu_pes: 32,
            mmu_mults_per_pe: 49,
            tile_n: 32,
            tile_k: 32,
            axi_bytes_per_cycle: 16,
            // long sequential weight bursts sustain near-peak DDR
            // efficiency; calibrated so Swin-T lands at the paper's
            // 48.1 FPS (EXPERIMENTS.md §TableV discusses the S/B deltas)
            mem_efficiency: 0.95,
            mmu_fill: 8,
            scu_lanes: 49,
            scu_depth: 24,
            gcu_lanes: 49,
            gcu_depth: 18,
            overlap_nonlinear: true,
            overlap_interunit: true,
            overlap_interlaunch: true,
            nl_design: nonlinear::NlDesign::Baseline,
        }
    }

    /// The paper configuration with cross-unit prefetch disabled: every
    /// scheduling unit runs strictly after its predecessor, reproducing
    /// the pre-pipeline-IR (sequential-unit) cycle counts exactly.
    /// Cross-launch prefetch is disabled too (no overlap anywhere).
    pub fn sequential(mut self) -> Self {
        self.overlap_interunit = false;
        self.overlap_interlaunch = false;
        self
    }

    /// Toggle cross-launch prefetch only (the warm-vs-cold ablation knob:
    /// `paper().interlaunch(false)` keeps intra-launch pipelining but
    /// makes every launch in a sequence pay the cold entry cost).
    pub fn interlaunch(mut self, on: bool) -> Self {
        self.overlap_interlaunch = on;
        self
    }

    /// Select a nonlinear-unit design (`paper().nonlinear(NlDesign::Peano)`
    /// etc.) — numerics, scheduler timing, busy intervals, resources and
    /// power all switch together.
    pub fn nonlinear(mut self, d: nonlinear::NlDesign) -> Self {
        self.nl_design = d;
        self
    }

    /// Peak MACs per cycle (= DSP count of the MMU).
    pub fn mmu_macs_per_cycle(&self) -> u64 {
        (self.mmu_pes * self.mmu_mults_per_pe) as u64
    }

    /// Effective external bandwidth in bytes/cycle.
    pub fn effective_bw(&self) -> f64 {
        self.axi_bytes_per_cycle as f64 * self.mem_efficiency
    }

    /// Cycles → milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_mhz * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_1568_dsp() {
        let c = AccelConfig::paper();
        assert_eq!(c.mmu_macs_per_cycle(), 1568);
    }

    #[test]
    fn overlap_builders() {
        let p = AccelConfig::paper();
        assert!(p.overlap_interunit && p.overlap_interlaunch);
        let s = AccelConfig::paper().sequential();
        assert!(!s.overlap_interunit && !s.overlap_interlaunch);
        let c = AccelConfig::paper().interlaunch(false);
        assert!(c.overlap_interunit && !c.overlap_interlaunch);
    }

    #[test]
    fn nonlinear_design_builder() {
        assert_eq!(AccelConfig::paper().nl_design, nonlinear::NlDesign::Baseline);
        let c = AccelConfig::paper().nonlinear(nonlinear::NlDesign::Peano);
        assert_eq!(c.nl_design, nonlinear::NlDesign::Peano);
    }

    #[test]
    fn cycle_conversion() {
        let c = AccelConfig::paper();
        // 200 MHz: 200k cycles per ms
        assert!((c.cycles_to_ms(200_000) - 1.0).abs() < 1e-9);
    }
}
