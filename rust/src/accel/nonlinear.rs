//! Pluggable nonlinear-unit designs — the trait-backed SCU/GCU design
//! space (ROADMAP: "alternative nonlinear-unit architectures").
//!
//! The paper commits to one design point: LUT/FMU softmax (Figs. 6–9)
//! and the polynomial + log-domain GELU (Fig. 10). PAPERS.md names two
//! published alternatives, and this module makes all three first-class
//! behind one trait so the whole stack — numerics, cycle model, resource
//! vector, power — switches together via [`crate::accel::AccelConfig`]:
//!
//! * [`NlDesign::Baseline`] — the paper's circuits, bit-for-bit the
//!   pre-trait `Scu::softmax` / `Gcu::gelu` behaviour (asserted by
//!   `rust/tests/nonlinear_designs.rs`).
//! * [`NlDesign::Quark`] — QUARK-style circuit sharing (arXiv
//!   2210.09573 family): Softmax and GELU are both "exp → normalise"
//!   once lowered to base-2, so one shared exp/recip datapath serves
//!   both units. Numerics are **identical** to the baseline (the same
//!   circuit, time-multiplexed); the cost is contention — *when both
//!   units are live* the shared pipe serialises them. The design prices
//!   its ops at II = 1 (sole ownership) and flags
//!   [`NonlinearDesign::shared_pipe`]; the pipeline IR arbitrates per
//!   window from the busy intervals and charges only the genuinely
//!   contended cycles (an earlier model applied a flat II = 2 to every
//!   window — over-charging the registry graphs, where softmax and GELU
//!   never co-live). The payoff is the GCU's LUT/FF/DSP largely folding
//!   into the SCU's.
//! * [`NlDesign::Peano`] — PEANO-style division/root-free normalisation
//!   ([`crate::approx::peano`]): the LOD + log₂ + EU reconstruction
//!   chain is replaced by a 3-multiply shift-add reciprocal. Shorter
//!   pipe (the DU + second EU stages collapse,
//!   [`PEANO_DEPTH_SAVE`] cycles of fill), fewer DSPs (one shared
//!   reciprocal tree instead of per-lane EU multipliers), bounded extra
//!   error (≤ 2⁻⁵ relative, measured end-to-end through
//!   `approx::error` and pinned in tests).
//!
//! Cycle formulas share the paper's pipeline shape: a `rows × passes`
//! (or `⌈elems/lanes⌉`) streaming term plus a fill. The design scales
//! the streaming term (QUARK's II = 2) or the fill (PEANO's shorter
//! pipe). Resource vectors are per-lane unit costs in the style of
//! [`super::resources`], calibrated against the respective papers'
//! reported deltas rather than a synthesiser (see DESIGN.md §5.1).
//!
//! Adding a fourth design = implement [`NonlinearDesign`] + add an
//! [`NlDesign`] arm; everything downstream (scheduler, pipeline busy
//! intervals, power, Pareto sweep, CLI `--design`) picks it up from the
//! config. See README "Nonlinear-unit design space".

use crate::approx::gelu::gelu_slice;
use crate::approx::peano::{gelu_slice_peano, softmax_rows_peano};
use crate::approx::softmax::softmax_rows;

use super::resources::Resources;
use super::scu::fmu_cycles;
use super::AccelConfig;

/// Pipeline-fill cycles the PEANO normalisation removes: the LOD (1) +
/// log₂ subtract (1) + second EU pass (4-stage PWL) collapse into the
/// 3-iteration reciprocal that overlaps the adder tree.
pub const PEANO_DEPTH_SAVE: u64 = 6;

/// Selector for the nonlinear-unit design. Carried by
/// [`AccelConfig::nl_design`]; `paper()` uses [`NlDesign::Baseline`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NlDesign {
    Baseline,
    Quark,
    Peano,
}

impl NlDesign {
    pub const ALL: [NlDesign; 3] = [NlDesign::Baseline, NlDesign::Quark, NlDesign::Peano];

    pub fn name(self) -> &'static str {
        match self {
            NlDesign::Baseline => "baseline",
            NlDesign::Quark => "quark",
            NlDesign::Peano => "peano",
        }
    }

    /// CLI/display name → design (`"paper"` is an alias for the
    /// baseline).
    pub fn by_name(s: &str) -> Option<NlDesign> {
        match s {
            "baseline" | "paper" => Some(NlDesign::Baseline),
            "quark" => Some(NlDesign::Quark),
            "peano" => Some(NlDesign::Peano),
            _ => None,
        }
    }

    /// The design's behaviour object (static dispatch table).
    pub fn design(self) -> &'static dyn NonlinearDesign {
        match self {
            NlDesign::Baseline => &BaselineDesign,
            NlDesign::Quark => &QuarkDesign,
            NlDesign::Peano => &PeanoDesign,
        }
    }
}

/// One SCU/GCU design: quantised kernels + cycle cost + resource
/// contribution. `softmax_cycles`/`gelu_cycles` price the unit's total
/// busy time (the pipeline IR's SCU/GCU busy intervals);
/// `softmax_exposed`/`gelu_exposed` price what lands on the critical
/// path when `overlap_nonlinear` hides the streaming term behind the
/// MMU's next window.
pub trait NonlinearDesign: std::fmt::Debug + Sync {
    fn kind(&self) -> NlDesign;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Softmax over a (rows × width) score matrix, Q7.8 → Q0.15.
    fn softmax(&self, scores: &[i32], width: usize) -> Vec<i32>;

    /// GELU over a tensor slice, Q7.8 → Q7.8.
    fn gelu(&self, xs: &[i32]) -> Vec<i32>;

    /// SCU busy cycles to softmax `rows` rows of `width` lanes.
    fn softmax_cycles(&self, cfg: &AccelConfig, rows: usize, width: usize) -> u64;

    /// SCU cycles exposed on the critical path under nonlinear overlap.
    fn softmax_exposed(&self, cfg: &AccelConfig, rows: usize, width: usize) -> u64;

    /// GCU busy cycles for `elems` activations.
    fn gelu_cycles(&self, cfg: &AccelConfig, elems: usize) -> u64;

    /// GCU cycles exposed on the critical path under nonlinear overlap.
    fn gelu_exposed(&self, cfg: &AccelConfig, elems: usize) -> u64;

    /// SCU resource vector (Table III row for this design).
    fn scu_resources(&self, cfg: &AccelConfig) -> Resources;

    /// GCU resource vector.
    fn gcu_resources(&self, cfg: &AccelConfig) -> Resources;

    /// Whether softmax and GELU time-multiplex one shared datapath.
    /// Designs return their *sole-ownership* (II = 1) cycle formulas;
    /// when this flag is set the pipeline IR arbitrates the shared pipe
    /// per window and charges contention only where the busy intervals
    /// actually overlap (see `pipeline::arbitrate_shared_pipe`).
    fn shared_pipe(&self) -> bool {
        false
    }
}

#[inline]
fn passes(cfg: &AccelConfig, width: usize) -> u64 {
    width.div_ceil(cfg.scu_lanes) as u64
}

#[inline]
fn gelu_tiles(cfg: &AccelConfig, elems: usize) -> u64 {
    elems.div_ceil(cfg.gcu_lanes) as u64
}

// --- Baseline: the paper's circuits --------------------------------------

/// The paper's design (Figs. 6–10): per-lane EU/LOD/DU, II = 1.
#[derive(Debug)]
pub struct BaselineDesign;

impl NonlinearDesign for BaselineDesign {
    fn kind(&self) -> NlDesign {
        NlDesign::Baseline
    }

    fn softmax(&self, scores: &[i32], width: usize) -> Vec<i32> {
        softmax_rows(scores, width)
    }

    fn gelu(&self, xs: &[i32]) -> Vec<i32> {
        gelu_slice(xs, false)
    }

    fn softmax_cycles(&self, cfg: &AccelConfig, rows: usize, width: usize) -> u64 {
        rows as u64 * passes(cfg, width) + fmu_cycles(width) + cfg.scu_depth
    }

    fn softmax_exposed(&self, cfg: &AccelConfig, _rows: usize, width: usize) -> u64 {
        fmu_cycles(width) + cfg.scu_depth
    }

    fn gelu_cycles(&self, cfg: &AccelConfig, elems: usize) -> u64 {
        gelu_tiles(cfg, elems) + cfg.gcu_depth
    }

    fn gelu_exposed(&self, cfg: &AccelConfig, _elems: usize) -> u64 {
        cfg.gcu_depth
    }

    fn scu_resources(&self, cfg: &AccelConfig) -> Resources {
        let lanes = cfg.scu_lanes as u32;
        Resources {
            dsp: lanes,
            lut: lanes * super::resources::SCU_LUT_PER_LANE,
            ff: lanes * super::resources::SCU_FF_PER_LANE,
            bram: 4,
        }
    }

    fn gcu_resources(&self, cfg: &AccelConfig) -> Resources {
        let lanes = cfg.gcu_lanes as u32;
        Resources {
            dsp: lanes * super::resources::GCU_DSP_PER_LANE,
            lut: lanes * super::resources::GCU_LUT_PER_LANE,
            ff: lanes * super::resources::GCU_FF_PER_LANE,
            bram: 4,
        }
    }
}

// --- QUARK-style: one shared exp/recip datapath --------------------------

/// QUARK-style circuit sharing: the SCU's exp + normalisation pipe also
/// serves the GCU (both ops are "2^v → normalise" in base-2 form), so
/// the GCU keeps only its polynomial front end and per-lane muxes. Same
/// numerics as the baseline — the shared circuit *is* the baseline
/// circuit — and so are the sole-ownership cycle formulas: a unit that
/// has the pipe to itself streams at II = 1 exactly like the baseline.
/// Contention exists only when softmax and GELU are live at once, and
/// that is the pipeline IR's call to make from the placed busy
/// intervals ([`NonlinearDesign::shared_pipe`]), not a flat per-op
/// surcharge.
const QUARK_GCU_LUT_PER_LANE: u32 = 560; // poly front end + share muxes
const QUARK_GCU_FF_PER_LANE: u32 = 80;
const QUARK_GCU_DSP_PER_LANE: u32 = 1; // x²/x³ fold into one shared mult

#[derive(Debug)]
pub struct QuarkDesign;

impl NonlinearDesign for QuarkDesign {
    fn kind(&self) -> NlDesign {
        NlDesign::Quark
    }

    fn softmax(&self, scores: &[i32], width: usize) -> Vec<i32> {
        softmax_rows(scores, width) // shared circuit = baseline numerics
    }

    fn gelu(&self, xs: &[i32]) -> Vec<i32> {
        gelu_slice(xs, false)
    }

    fn softmax_cycles(&self, cfg: &AccelConfig, rows: usize, width: usize) -> u64 {
        BaselineDesign.softmax_cycles(cfg, rows, width) // II = 1 when sole owner
    }

    fn softmax_exposed(&self, cfg: &AccelConfig, rows: usize, width: usize) -> u64 {
        BaselineDesign.softmax_exposed(cfg, rows, width)
    }

    fn gelu_cycles(&self, cfg: &AccelConfig, elems: usize) -> u64 {
        BaselineDesign.gelu_cycles(cfg, elems)
    }

    fn gelu_exposed(&self, cfg: &AccelConfig, elems: usize) -> u64 {
        BaselineDesign.gelu_exposed(cfg, elems)
    }

    fn scu_resources(&self, cfg: &AccelConfig) -> Resources {
        BaselineDesign.scu_resources(cfg) // the shared pipe lives here
    }

    fn shared_pipe(&self) -> bool {
        true // the IR arbitrates contended windows at lowering
    }

    fn gcu_resources(&self, cfg: &AccelConfig) -> Resources {
        let lanes = cfg.gcu_lanes as u32;
        Resources {
            dsp: lanes * QUARK_GCU_DSP_PER_LANE,
            lut: lanes * QUARK_GCU_LUT_PER_LANE,
            ff: lanes * QUARK_GCU_FF_PER_LANE,
            bram: 4,
        }
    }
}

// --- PEANO-style: division/root-free normalisation -----------------------

/// PEANO-style design: [`crate::approx::peano`] kernels. One shared
/// reciprocal tree per unit replaces the per-lane DU + second-EU chain:
/// the SCU drops to roughly one DSP per *pair* of lanes (+ the
/// reciprocal's 3 multipliers + tree glue), the GCU to one multiplier
/// per lane (x²·x fused) + the tree, and both pipes lose
/// [`PEANO_DEPTH_SAVE`] fill cycles. The trade is the reciprocal's
/// bounded truncation error (≤ 2⁻⁵ relative — in practice it *reduces*
/// end-to-end error vs the baseline's LOD ripple, see the pinned
/// goldens).
const PEANO_SCU_LUT_PER_LANE: u32 = 620; // no per-lane LOD/DU/EU-2
const PEANO_SCU_FF_PER_LANE: u32 = 310;
const PEANO_GCU_LUT_PER_LANE: u32 = 820;
const PEANO_GCU_FF_PER_LANE: u32 = 100;

#[derive(Debug)]
pub struct PeanoDesign;

impl NonlinearDesign for PeanoDesign {
    fn kind(&self) -> NlDesign {
        NlDesign::Peano
    }

    fn softmax(&self, scores: &[i32], width: usize) -> Vec<i32> {
        softmax_rows_peano(scores, width)
    }

    fn gelu(&self, xs: &[i32]) -> Vec<i32> {
        gelu_slice_peano(xs)
    }

    fn softmax_cycles(&self, cfg: &AccelConfig, rows: usize, width: usize) -> u64 {
        rows as u64 * passes(cfg, width)
            + fmu_cycles(width)
            + cfg.scu_depth.saturating_sub(PEANO_DEPTH_SAVE)
    }

    fn softmax_exposed(&self, cfg: &AccelConfig, _rows: usize, width: usize) -> u64 {
        fmu_cycles(width) + cfg.scu_depth.saturating_sub(PEANO_DEPTH_SAVE)
    }

    fn gelu_cycles(&self, cfg: &AccelConfig, elems: usize) -> u64 {
        gelu_tiles(cfg, elems) + cfg.gcu_depth.saturating_sub(PEANO_DEPTH_SAVE)
    }

    fn gelu_exposed(&self, cfg: &AccelConfig, _elems: usize) -> u64 {
        cfg.gcu_depth.saturating_sub(PEANO_DEPTH_SAVE)
    }

    fn scu_resources(&self, cfg: &AccelConfig) -> Resources {
        let lanes = cfg.scu_lanes as u32;
        Resources {
            // one DSP per lane pair (paired EU mults) + reciprocal tree
            dsp: lanes.div_ceil(2) + 8,
            lut: lanes * PEANO_SCU_LUT_PER_LANE,
            ff: lanes * PEANO_SCU_FF_PER_LANE,
            bram: 4,
        }
    }

    fn gcu_resources(&self, cfg: &AccelConfig) -> Resources {
        let lanes = cfg.gcu_lanes as u32;
        Resources {
            dsp: lanes + 4, // fused cubic mult per lane + shared recip
            lut: lanes * PEANO_GCU_LUT_PER_LANE,
            ff: lanes * PEANO_GCU_FF_PER_LANE,
            bram: 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AccelConfig {
        AccelConfig::paper()
    }

    #[test]
    fn names_round_trip() {
        for d in NlDesign::ALL {
            assert_eq!(NlDesign::by_name(d.name()), Some(d));
            assert_eq!(d.design().kind(), d);
        }
        assert_eq!(NlDesign::by_name("paper"), Some(NlDesign::Baseline));
        assert_eq!(NlDesign::by_name("nope"), None);
    }

    #[test]
    fn baseline_formulas_match_paper_pins() {
        let c = cfg();
        let d = NlDesign::Baseline.design();
        // the Scu/Gcu test pins, via the trait
        assert_eq!(d.softmax_cycles(&c, 49, 49), 49 + 6 + c.scu_depth);
        assert_eq!(d.gelu_cycles(&c, 49), 1 + 18);
        assert_eq!(d.gelu_cycles(&c, 490), 10 + 18);
    }

    #[test]
    fn quark_prices_sole_ownership_at_baseline_rates() {
        // Re-pinned with the per-window arbitration fix (PR 9): the old
        // model charged a flat II = 2 on every op — here the design's
        // formulas are the sole-ownership (= baseline) rates, and only
        // the pipeline IR adds contention where SCU/GCU busy intervals
        // actually overlap (flagged via shared_pipe).
        let c = cfg();
        let b = NlDesign::Baseline.design();
        let q = NlDesign::Quark.design();
        assert_eq!(q.softmax_cycles(&c, 100, 49), b.softmax_cycles(&c, 100, 49));
        assert_eq!(q.softmax_exposed(&c, 100, 49), b.softmax_exposed(&c, 100, 49));
        assert_eq!(q.gelu_cycles(&c, 490), b.gelu_cycles(&c, 490));
        assert_eq!(q.gelu_exposed(&c, 490), b.gelu_exposed(&c, 490));
        // only quark time-multiplexes one datapath
        assert!(q.shared_pipe());
        assert!(!b.shared_pipe());
        assert!(!NlDesign::Peano.design().shared_pipe());
        // numerics are the shared (= baseline) circuit, bit for bit
        let scores: Vec<i32> = (0..98).map(|i| ((i * 37) % 401) - 200).collect();
        assert_eq!(q.softmax(&scores, 49), b.softmax(&scores, 49));
        let xs: Vec<i32> = (-20..20).map(|i| i * 77).collect();
        assert_eq!(q.gelu(&xs), b.gelu(&xs));
    }

    #[test]
    fn peano_shortens_the_pipe() {
        let c = cfg();
        let b = NlDesign::Baseline.design();
        let p = NlDesign::Peano.design();
        assert_eq!(
            b.softmax_cycles(&c, 100, 49) - p.softmax_cycles(&c, 100, 49),
            PEANO_DEPTH_SAVE
        );
        assert_eq!(
            b.gelu_exposed(&c, 490) - p.gelu_exposed(&c, 490),
            PEANO_DEPTH_SAVE
        );
        // different normalisation, different outputs
        let scores: Vec<i32> = (0..49).map(|i| ((i * 37) % 401) - 200).collect();
        assert_ne!(p.softmax(&scores, 49), b.softmax(&scores, 49));
    }

    #[test]
    fn resource_vectors_table3_and_deltas() {
        let c = cfg();
        let b = NlDesign::Baseline.design();
        assert_eq!(b.scu_resources(&c).dsp, 49);
        assert_eq!(b.gcu_resources(&c).dsp, 98);
        let q = NlDesign::Quark.design();
        assert_eq!(q.scu_resources(&c), b.scu_resources(&c));
        assert_eq!(q.gcu_resources(&c).dsp, 49);
        assert!(q.gcu_resources(&c).lut < b.gcu_resources(&c).lut);
        let p = NlDesign::Peano.design();
        assert_eq!(p.scu_resources(&c).dsp, 33);
        assert_eq!(p.gcu_resources(&c).dsp, 53);
        // PEANO's pitch: strictly cheaper on every fabric axis
        for (pr, br) in [
            (p.scu_resources(&c), b.scu_resources(&c)),
            (p.gcu_resources(&c), b.gcu_resources(&c)),
        ] {
            assert!(pr.dsp < br.dsp && pr.lut < br.lut && pr.ff < br.ff);
        }
    }
}
