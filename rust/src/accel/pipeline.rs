//! Pipeline schedule IR — the **single timing source** of the crate.
//!
//! [`super::control::Scheduler`] prices every op (compute / nonlinear /
//! memory cycles) and groups ops into scheduling units, but deliberately
//! says nothing about *when* anything happens. This module lowers those
//! priced units into a typed event schedule: per-resource busy intervals
//! for the four hardware engines (MRU/MWU weight+activation streaming,
//! MMU matrix compute, SCU softmax, GCU GELU) placed on one absolute
//! cycle timeline.
//!
//! Everything that needs launch timing consumes a [`PipelineSchedule`]:
//!
//! * [`super::sim::Simulator`] aggregates it into a `SimResult`
//!   (Table V FPS/GOPS);
//! * [`super::trace::Timeline`] renders its segments as a Chrome trace;
//! * [`crate::server::SimEngine`] queries [`PipelineSchedule::launch_cycles`]
//!   for batch-*b* launch costs;
//! * [`crate::server::PjrtEngine`] warms its cold-start service estimate
//!   from it, and the fleet router inherits both through
//!   [`crate::server::Engine::service_estimate`].
//!
//! ## Placement rules
//!
//! Within a unit, the weight/activation stream and the compute chain
//! start together and the unit completes when both are done (the paper's
//! intra-unit double buffering, §IV.A). Across units two modes exist:
//!
//! * `overlap_interunit = false` — units execute strictly back-to-back:
//!   unit *i+1* starts at unit *i*'s completion. This reproduces the
//!   sequential-unit totals the Table V calibration was performed under.
//! * `overlap_interunit = true` (the [`AccelConfig::paper`] default) —
//!   cross-unit double buffering: unit *i+1*'s stream may start as soon
//!   as the MRU frees (and the weight buffer slot of unit *i−1* is
//!   released, a two-deep prefetch), not after unit *i*'s critical path.
//!   Compute still serialises on the MMU and never outruns its stream.
//!
//! Batch replay: a launch of batch *b* re-issues each unit's compute
//! events *b* times while the once-per-launch weight stream is shared —
//! which is exactly why batching pays on this bandwidth-bound design.

use crate::model::config::SwinVariant;
use crate::model::graph::{GemmKind, OpKind, WorkloadGraph};
use crate::util::json::Json;

use super::control::Scheduler;
use super::AccelConfig;

/// Which hardware engine a segment occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    /// Memory read/write units streaming over the AXI interface.
    Mru,
    Mmu,
    Scu,
    Gcu,
}

impl Resource {
    pub const ALL: [Resource; 4] = [Resource::Mru, Resource::Mmu, Resource::Scu, Resource::Gcu];

    pub fn name(self) -> &'static str {
        match self {
            Resource::Mru => "MRU/MWU",
            Resource::Mmu => "MMU",
            Resource::Scu => "SCU",
            Resource::Gcu => "GCU",
        }
    }
}

/// One busy interval on one resource, in absolute launch cycles.
#[derive(Debug, Clone)]
pub struct Segment {
    pub unit: Resource,
    pub label: String,
    pub start: u64,
    pub end: u64,
}

impl Segment {
    pub fn dur(&self) -> u64 {
        self.end - self.start
    }
}

/// One op's priced contribution inside a unit (for segment emission).
#[derive(Debug, Clone)]
struct OpCost {
    label: String,
    compute: u64,
    nonlinear: u64,
    nonlinear_exposed: u64,
    /// Which nonlinear engine runs this op (meaningful when nonlinear > 0).
    nl_unit: Resource,
}

/// A scheduling unit's lowered cost vector: everything the placement
/// recurrence needs, with per-resource busy totals broken out.
#[derive(Debug, Clone)]
pub struct UnitCost {
    pub label: String,
    pub stage: usize,
    /// Critical-path compute per batch replica: MMU cycles plus the
    /// exposed nonlinear fill.
    pub compute: u64,
    /// Once-per-launch external-memory stream cycles (MRU + MWU).
    pub mem: u64,
    /// MMU busy cycles per replica.
    pub mmu: u64,
    /// SCU busy cycles per replica (full softmax occupancy, not fill).
    pub scu: u64,
    /// GCU busy cycles per replica.
    pub gcu: u64,
    /// Exposed nonlinear fill cycles per replica.
    pub nonlinear_exposed: u64,
    ops: Vec<OpCost>,
}

/// Absolute placement of one unit on the launch timeline.
#[derive(Debug, Clone, Copy)]
pub struct UnitSpan {
    pub stream_start: u64,
    pub stream_end: u64,
    pub compute_start: u64,
    /// Unit completion: compute chain drained *and* stream landed.
    pub compute_end: u64,
}

/// The lowered event schedule for one model variant on one configuration.
#[derive(Debug, Clone)]
pub struct PipelineSchedule {
    pub variant: &'static str,
    pub cfg: AccelConfig,
    pub units: Vec<UnitCost>,
    /// Single-image launch cycles (`launch_cycles(1)`, cached).
    pub total_cycles: u64,
}

impl PipelineSchedule {
    /// Build the schedule for a variant: graph → priced units → IR.
    pub fn for_variant(variant: &SwinVariant, cfg: AccelConfig) -> Self {
        let graph = WorkloadGraph::build(variant);
        let scheduler = Scheduler::new(cfg);
        Self::lower(&graph, &scheduler)
    }

    /// Lower the scheduler's priced units into the event IR.
    pub fn lower(graph: &WorkloadGraph, scheduler: &Scheduler) -> Self {
        let sched_units = scheduler.schedule(graph);
        let mut ops = graph.ops.iter();
        let mut units = Vec::with_capacity(sched_units.len());
        for su in &sched_units {
            let mut unit = UnitCost {
                label: su.label.clone(),
                stage: su.stage,
                compute: su.compute() + su.nonlinear_exposed(),
                mem: su.mem(),
                mmu: su.compute(),
                scu: 0,
                gcu: 0,
                nonlinear_exposed: su.nonlinear_exposed(),
                ops: Vec::with_capacity(su.timings.len()),
            };
            for t in &su.timings {
                let op = ops.next().expect("schedule/graph op mismatch");
                let nl_unit = match op.op {
                    OpKind::Softmax { .. } => Resource::Scu,
                    _ => Resource::Gcu,
                };
                match nl_unit {
                    Resource::Scu => unit.scu += t.nonlinear_cycles,
                    _ => unit.gcu += t.nonlinear_cycles,
                }
                unit.ops.push(OpCost {
                    label: format!("{}:{}", su.label, kind_name(&op.op)),
                    compute: t.compute_cycles,
                    nonlinear: t.nonlinear_cycles,
                    nonlinear_exposed: t.nonlinear_exposed,
                    nl_unit,
                });
            }
            units.push(unit);
        }
        let mut s = PipelineSchedule {
            variant: graph.variant,
            cfg: scheduler.cfg.clone(),
            units,
            total_cycles: 0,
        };
        s.total_cycles = s.launch_cycles(1);
        s
    }

    /// Place every unit on the launch timeline for a batch-`batch` launch.
    ///
    /// The recurrence (see module docs): unit *i*'s stream starts when the
    /// MRU frees and the two-deep weight buffer has a slot (pipelined
    /// mode) or at unit *i−1*'s completion (sequential mode); compute
    /// starts when the MMU frees but never before the unit's own stream
    /// begins; completion waits for both compute and stream.
    pub fn placements(&self, batch: usize) -> Vec<UnitSpan> {
        let b = batch.max(1) as u64;
        let mut spans: Vec<UnitSpan> = Vec::with_capacity(self.units.len());
        let mut prev_stream_end = 0u64; // MRU frees
        let mut prev_ce = 0u64; // compute_end(i-1)
        let mut prev2_ce = 0u64; // compute_end(i-2): freed buffer slot
        for u in &self.units {
            let c = b * u.compute;
            let (stream_start, compute_start) = if self.cfg.overlap_interunit {
                let ss = prev_stream_end.max(prev2_ce);
                (ss, prev_ce.max(ss))
            } else {
                (prev_ce, prev_ce)
            };
            let stream_end = stream_start + u.mem;
            let compute_end = (compute_start + c).max(stream_end);
            spans.push(UnitSpan {
                stream_start,
                stream_end,
                compute_start,
                compute_end,
            });
            prev_stream_end = stream_end;
            prev2_ce = prev_ce;
            prev_ce = compute_end;
        }
        spans
    }

    /// Modelled cycles for one launch of `batch` images: the weight
    /// stream is issued once, compute events replay per image.
    pub fn launch_cycles(&self, batch: usize) -> u64 {
        self.placements(batch).last().map_or(0, |s| s.compute_end)
    }

    /// Modelled service time of one launch of `batch` images.
    pub fn launch_ms(&self, batch: usize) -> f64 {
        self.cfg.cycles_to_ms(self.launch_cycles(batch))
    }

    /// Busy cycles of one resource over a single-image launch.
    pub fn busy(&self, r: Resource) -> u64 {
        self.units
            .iter()
            .map(|u| match r {
                Resource::Mru => u.mem,
                Resource::Mmu => u.mmu,
                Resource::Scu => u.scu,
                Resource::Gcu => u.gcu,
            })
            .sum()
    }

    /// Per-stage cycle totals: each unit contributes the timeline it
    /// *advances* (`compute_end(i) − compute_end(i−1)`), so the stage
    /// totals partition `launch_cycles(batch)` exactly — overlapped
    /// prefetch time is attributed to the unit that hides it.
    ///
    /// Panics if any unit's stage index is out of range: every op must
    /// carry an exact stage (no clamping — see `Simulator::aggregate`).
    pub fn stage_spans(&self, stages: usize, batch: usize) -> Vec<u64> {
        let mut out = vec![0u64; stages];
        let mut prev = 0u64;
        for (u, sp) in self.units.iter().zip(self.placements(batch)) {
            assert!(
                u.stage < stages,
                "unit {} carries stage {} outside 0..{stages}",
                u.label,
                u.stage
            );
            out[u.stage] += sp.compute_end - prev;
            prev = sp.compute_end;
        }
        out
    }

    /// The full event list of a batch-`batch` launch: one stream segment
    /// per unit plus per-op MMU/SCU/GCU segments per batch replica.
    /// Nonlinear segments carry their *full* engine occupancy (the SCU
    /// drains rows while the MMU moves on); only the fill is exposed on
    /// the compute chain.
    pub fn segments(&self, batch: usize) -> Vec<Segment> {
        let mut segs = Vec::new();
        for (u, sp) in self.units.iter().zip(self.placements(batch)) {
            if u.mem > 0 {
                segs.push(Segment {
                    unit: Resource::Mru,
                    label: format!("{}:stream", u.label),
                    start: sp.stream_start,
                    end: sp.stream_end,
                });
            }
            let mut mmu_t = sp.compute_start;
            let mut nl_t = sp.compute_start;
            for _ in 0..batch.max(1) {
                for op in &u.ops {
                    if op.compute > 0 {
                        segs.push(Segment {
                            unit: Resource::Mmu,
                            label: op.label.clone(),
                            start: mmu_t,
                            end: mmu_t + op.compute,
                        });
                        mmu_t += op.compute;
                    }
                    if op.nonlinear_exposed > 0 {
                        let start = mmu_t.max(nl_t);
                        segs.push(Segment {
                            unit: op.nl_unit,
                            label: op.label.clone(),
                            start,
                            end: start + op.nonlinear.max(1),
                        });
                        nl_t = start + op.nonlinear_exposed;
                        mmu_t += op.nonlinear_exposed;
                    }
                }
            }
        }
        segs
    }

    /// Compact JSON summary for the metrics endpoint and reports.
    pub fn summary_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("variant".into(), Json::Str(self.variant.into()));
        obj.insert(
            "overlap_interunit".into(),
            Json::Bool(self.cfg.overlap_interunit),
        );
        obj.insert("total_cycles".into(), Json::Num(self.total_cycles as f64));
        obj.insert(
            "latency_ms".into(),
            Json::Num(self.cfg.cycles_to_ms(self.total_cycles)),
        );
        let mut busy = std::collections::BTreeMap::new();
        for r in Resource::ALL {
            busy.insert(r.name().into(), Json::Num(self.busy(r) as f64));
        }
        obj.insert("busy_cycles".into(), Json::Obj(busy));
        let mut launches = std::collections::BTreeMap::new();
        for b in [1usize, 2, 4, 8] {
            launches.insert(b.to_string(), Json::Num(self.launch_cycles(b) as f64));
        }
        obj.insert("launch_cycles".into(), Json::Obj(launches));
        Json::Obj(obj)
    }
}

pub(crate) fn kind_name(op: &OpKind) -> &'static str {
    match op {
        OpKind::Gemm { kind, .. } => match kind {
            GemmKind::PatchEmbed => "patch_embed",
            GemmKind::Qkv => "qkv",
            GemmKind::Scores => "scores",
            GemmKind::AttnV => "attn_v",
            GemmKind::Proj => "proj",
            GemmKind::Mlp1 => "mlp1",
            GemmKind::Mlp2 => "mlp2",
            GemmKind::PatchMerge => "merge",
            GemmKind::Head => "head",
        },
        OpKind::Softmax { .. } => "softmax",
        OpKind::Gelu { .. } => "gelu",
        OpKind::Add { .. } => "add",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, MICRO, SMALL, TINY};

    fn schedule(v: &'static SwinVariant, cfg: AccelConfig) -> PipelineSchedule {
        PipelineSchedule::for_variant(v, cfg)
    }

    #[test]
    fn sequential_total_is_sum_of_unit_critical_paths() {
        let s = schedule(&TINY, AccelConfig::paper().sequential());
        let by_units: u64 = s.units.iter().map(|u| u.compute.max(u.mem)).sum();
        assert_eq!(s.total_cycles, by_units);
    }

    #[test]
    fn pipelined_never_slower_never_breaks_resource_bounds() {
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let pipe = schedule(v, AccelConfig::paper());
            let seq = schedule(v, AccelConfig::paper().sequential());
            assert!(pipe.total_cycles <= seq.total_cycles, "{}", v.name);
            // lower bounds: both serialized resource chains must fit
            let compute: u64 = pipe.units.iter().map(|u| u.compute).sum();
            assert!(pipe.total_cycles >= compute, "{}", v.name);
            assert!(pipe.total_cycles >= pipe.busy(Resource::Mru), "{}", v.name);
        }
    }

    #[test]
    fn prefetch_gains_on_tiny_are_modest_but_real() {
        // swin-t: pipelined 4 850 504 vs sequential 4 950 506 cycles (the
        // workload is bandwidth-bound, so cross-unit prefetch only hides
        // the compute-bound attention units)
        let pipe = schedule(&TINY, AccelConfig::paper());
        let seq = schedule(&TINY, AccelConfig::paper().sequential());
        assert!(pipe.total_cycles < seq.total_cycles);
        let gain = seq.total_cycles as f64 / pipe.total_cycles as f64;
        assert!((1.005..1.10).contains(&gain), "gain={gain}");
    }

    #[test]
    fn placements_are_causally_ordered() {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            let s = schedule(&TINY, cfg);
            let spans = s.placements(1);
            let mut prev_se = 0u64;
            let mut prev_ce = 0u64;
            for sp in &spans {
                assert!(sp.stream_start >= prev_se, "MRU serialises streams");
                assert!(sp.compute_start >= prev_ce, "MMU serialises compute");
                assert!(sp.compute_start >= sp.stream_start);
                assert!(sp.compute_end >= sp.stream_end);
                prev_se = sp.stream_end;
                prev_ce = sp.compute_end;
            }
            assert_eq!(spans.last().unwrap().compute_end, s.total_cycles);
        }
    }

    #[test]
    fn batch_replay_shares_the_weight_stream() {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            let s = schedule(&TINY, cfg);
            let c1 = s.launch_cycles(1);
            let c8 = s.launch_cycles(8);
            assert!(c8 < 8 * c1, "c1={c1} c8={c8}");
            assert!(c8 >= c1);
            // per-image cost never increases with batch
            let per = |b: usize| s.launch_cycles(b) as f64 / b as f64;
            assert!(per(2) <= per(1));
            assert!(per(4) <= per(2));
            assert!(per(8) <= per(4));
        }
    }

    #[test]
    fn segment_busy_matches_unit_totals() {
        let s = schedule(&MICRO, AccelConfig::paper());
        let segs = s.segments(1);
        for r in Resource::ALL {
            let seg_busy: u64 = segs.iter().filter(|e| e.unit == r).map(Segment::dur).sum();
            assert_eq!(seg_busy, s.busy(r), "{}", r.name());
        }
    }

    #[test]
    fn segments_stay_inside_the_launch_window() {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            let s = schedule(&MICRO, cfg);
            for e in s.segments(1) {
                assert!(e.end >= e.start);
                assert!(e.end <= s.total_cycles, "{} overruns", e.label);
            }
        }
    }

    #[test]
    fn stage_spans_partition_the_total() {
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let s = schedule(v, AccelConfig::paper());
            let stages = v.num_stages();
            for b in [1usize, 4] {
                let spans = s.stage_spans(stages, b);
                assert_eq!(spans.iter().sum::<u64>(), s.launch_cycles(b), "{}", v.name);
            }
        }
    }

    #[test]
    fn summary_json_roundtrips() {
        let s = schedule(&MICRO, AccelConfig::paper());
        let j = Json::parse(&s.summary_json().to_string()).unwrap();
        assert_eq!(j.get("variant").unwrap().as_str(), Some("swin-micro"));
        assert_eq!(
            j.get("total_cycles").unwrap().as_usize().unwrap() as u64,
            s.total_cycles
        );
        assert!(j.get("busy_cycles").unwrap().get("MMU").is_some());
        assert!(j.get("launch_cycles").unwrap().get("8").is_some());
    }
}
