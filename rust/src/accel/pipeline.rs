//! Pipeline schedule IR — the **single timing source** of the crate.
//!
//! [`super::control::Scheduler`] prices every op (compute / nonlinear /
//! memory cycles) and groups ops into scheduling units, but deliberately
//! says nothing about *when* anything happens. This module lowers those
//! priced units into a typed event schedule: per-resource busy intervals
//! for the four hardware engines (MRU/MWU weight+activation streaming,
//! MMU matrix compute, SCU softmax, GCU GELU) placed on one absolute
//! cycle timeline.
//!
//! Everything that needs launch timing consumes a [`PipelineSchedule`]:
//!
//! * [`super::sim::Simulator`] aggregates it into a `SimResult`
//!   (Table V FPS/GOPS);
//! * [`super::trace::Timeline`] renders its segments as a Chrome trace;
//! * [`crate::server::SimEngine`] queries [`PipelineSchedule::launch_cycles`]
//!   for batch-*b* launch costs;
//! * [`crate::server::PjrtEngine`] warms its cold-start service estimate
//!   from it, and the fleet router inherits both through
//!   [`crate::server::Engine::service_estimate`].
//!
//! ## Placement rules
//!
//! Within a unit, the weight/activation stream and the compute chain
//! start together and the unit completes when both are done (the paper's
//! intra-unit double buffering, §IV.A). Across units two modes exist:
//!
//! * `overlap_interunit = false` — units execute strictly back-to-back:
//!   unit *i+1* starts at unit *i*'s completion. This reproduces the
//!   sequential-unit totals the Table V calibration was performed under.
//! * `overlap_interunit = true` (the [`AccelConfig::paper`] default) —
//!   cross-unit double buffering: unit *i+1*'s stream may start as soon
//!   as the MRU frees *and* the weight buffer has a free slot. The slot
//!   gate is the per-stage capacity constraint of
//!   [`super::buffers::BufferPlan`]: a stage whose stream window is
//!   `1/d` of the weight buffer admits `d` in-flight unit streams, so
//!   unit *g*'s stream waits for unit *g−d*'s completion
//!   ([`BufferPlan::prefetch_depth`]; the last stage is double-buffered,
//!   `d = 2`). Compute still serialises on the MMU and never outruns its
//!   stream, and a **cold** launch entry additionally pays the first
//!   window's fill — compute cannot begin until one double-buffer window
//!   has landed.
//!
//! Batch replay: a launch of batch *b* re-issues each unit's compute
//! events *b* times while the once-per-launch weight stream is shared —
//! which is exactly why batching pays on this bandwidth-bound design.
//!
//! ## Launch sequences (cross-launch prefetch)
//!
//! [`PipelineSchedule::sequence`] places back-to-back launches on the
//! same absolute per-resource timeline ([`SequenceSchedule`]). With
//! [`AccelConfig::overlap_interlaunch`] **off**, a barrier separates
//! launches and the sequence costs exactly `Σ launch_cycles(bᵢ)`. With
//! it **on** (the paper default), launch *N+1*'s weight stream begins
//! while launch *N* still computes — gated by the MRU and the same
//! per-stage buffer headroom — and, because launch *N+1*'s inputs do not
//! depend on launch *N*'s outputs, its compute starts the moment the MMU
//! frees (not at launch *N*'s full completion). The entry fill is only
//! waived to the extent the stream really ran ahead: a warm entry's
//! compute still never starts before one window of its own stream has
//! landed.
//! The per-launch increment of an infinite warm queue is
//! [`PipelineSchedule::steady_launch_cycles`]; it is strictly below the
//! cold cost whenever the cold launch leaves the entry fill or an MMU
//! idle tail exposed, and equals it when a launch is purely
//! stream-bound (small batches hug the MRU floor).
//!
//! ## Shared cost tables (the serving hot path)
//!
//! Sequences are placed **streamingly** by [`SequencePlacer`] — one
//! launch appended at a time onto a persistent [`Placer`], O(units) per
//! append with O(prefetch-depth) retained state — and
//! `steady_launch_cycles` is the fixed point of that append step (the
//! normalized placer state repeating exactly), not a re-placement of
//! ever-longer sequences. Serving consumers never even pay the append:
//! [`CostTable`] memoizes the cold ([`PipelineSchedule::launch_cycles`])
//! and warm ([`PipelineSchedule::steady_launch_cycles`]) cycles per
//! bucket once per (variant, [`AccelConfig`]) and is shared via
//! `Arc` across every `SimEngine`, `ServicePrior` and router card of
//! the same variant, so an N-card homogeneous fleet lowers the graph
//! and converges the warm costs exactly once.

use std::sync::Arc;

use crate::model::config::SwinVariant;
use crate::model::graph::{GemmKind, OpKind, WorkloadGraph};
use crate::util::json::Json;

use super::buffers::BufferPlan;
use super::control::Scheduler;
use super::memory::MemoryModel;
use super::AccelConfig;

// The model-sharding layer builds on this IR (per-shard schedules, the
// inter-card link, sharded sequences); its types live in
// [`super::shard`] and are re-exported here so consumers read one
// pipeline namespace.
pub use super::shard::{
    Shard, ShardCostTable, ShardPlan, ShardedLaunchSpan, ShardedSchedule, ShardedSequencePlacer,
};

/// Which hardware engine a segment occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    /// Memory read/write units streaming over the AXI interface.
    Mru,
    Mmu,
    Scu,
    Gcu,
    /// Inter-card link of a sharded pipeline: the activation transfer at
    /// a stage cut (one link per adjacent card pair; see
    /// [`super::shard::ShardedSchedule`]). Single-card schedules never
    /// emit it.
    Link,
}

impl Resource {
    pub const ALL: [Resource; 5] = [
        Resource::Mru,
        Resource::Mmu,
        Resource::Scu,
        Resource::Gcu,
        Resource::Link,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Resource::Mru => "MRU/MWU",
            Resource::Mmu => "MMU",
            Resource::Scu => "SCU",
            Resource::Gcu => "GCU",
            Resource::Link => "LINK",
        }
    }
}

/// One busy interval on one resource, in absolute launch cycles.
#[derive(Debug, Clone)]
pub struct Segment {
    pub unit: Resource,
    pub label: String,
    pub start: u64,
    pub end: u64,
}

impl Segment {
    pub fn dur(&self) -> u64 {
        self.end - self.start
    }
}

/// One op's priced contribution inside a unit (for segment emission).
#[derive(Debug, Clone)]
struct OpCost {
    label: String,
    compute: u64,
    nonlinear: u64,
    nonlinear_exposed: u64,
    /// Which nonlinear engine runs this op (meaningful when nonlinear > 0).
    nl_unit: Resource,
}

/// A scheduling unit's lowered cost vector: everything the placement
/// recurrence needs, with per-resource busy totals broken out.
#[derive(Debug, Clone)]
pub struct UnitCost {
    pub label: String,
    pub stage: usize,
    /// Critical-path compute per batch replica: MMU cycles plus the
    /// exposed nonlinear fill.
    pub compute: u64,
    /// Once-per-launch external-memory stream cycles (MRU + MWU).
    pub mem: u64,
    /// MMU busy cycles per replica.
    pub mmu: u64,
    /// SCU busy cycles per replica (full softmax occupancy, not fill).
    pub scu: u64,
    /// GCU busy cycles per replica.
    pub gcu: u64,
    /// Exposed nonlinear fill cycles per replica.
    pub nonlinear_exposed: u64,
    ops: Vec<OpCost>,
}

/// Absolute placement of one unit on the launch timeline.
#[derive(Debug, Clone, Copy)]
pub struct UnitSpan {
    pub stream_start: u64,
    pub stream_end: u64,
    pub compute_start: u64,
    /// Unit completion: compute chain drained *and* stream landed.
    pub compute_end: u64,
}

/// Absolute placement of one launch inside a [`SequenceSchedule`].
#[derive(Debug, Clone)]
pub struct LaunchSpan {
    pub batch: usize,
    /// First event of the launch (its first unit's stream start).
    pub start: u64,
    /// Completion of the launch (its last unit's compute end).
    pub end: u64,
    /// Per-unit spans, absolute on the sequence timeline.
    pub spans: Vec<UnitSpan>,
}

/// A sequence of back-to-back launches placed on one absolute timeline —
/// the launch-sequence IR the continuous batcher's steady state is
/// modelled by (see the module docs).
#[derive(Debug, Clone)]
pub struct SequenceSchedule {
    pub variant: &'static str,
    /// Whether cross-launch prefetch was enabled for this placement.
    pub overlap_interlaunch: bool,
    pub launches: Vec<LaunchSpan>,
    pub total_cycles: u64,
}

/// The lowered event schedule for one model variant on one configuration.
#[derive(Debug, Clone)]
pub struct PipelineSchedule {
    pub variant: &'static str,
    pub cfg: AccelConfig,
    pub units: Vec<UnitCost>,
    /// Per-stage prefetch headroom from [`BufferPlan::prefetch_depths`]
    /// (how many unit streams of that stage fit the weight buffer).
    pub prefetch_depths: Vec<usize>,
    /// Per-stage cycles to land one stream window (the cold entry fill).
    pub window_fills: Vec<u64>,
    /// Single-image launch cycles (`launch_cycles(1)`, cached).
    pub total_cycles: u64,
}

/// How a unit enters the timeline (see module docs).
enum Entry {
    /// Strictly after the previous unit's completion (sequential mode).
    Sequential,
    /// Stream as early as MRU + buffer slot allow; compute after the
    /// previous unit completes and `fill` cycles of the stream landed.
    Pipelined { fill: u64 },
    /// Warm cross-launch boundary: stream as early as MRU + slot allow;
    /// compute the moment the MMU frees — but never before `fill` cycles
    /// of its own stream landed. When the stream ran ahead during the
    /// previous launch (`ss + fill ≤ mmu_free`, the usual warm case) the
    /// fill is fully hidden; when the MRU only freed late, even a warm
    /// launch waits for its first window like a cold one.
    Warm { fill: u64 },
}

/// Placement state threaded across units (and, for sequences, across
/// launches): per-resource frontiers plus the slot-release history the
/// buffer-headroom gate consults. The history is a ring bounded by the
/// deepest prefetch depth the schedule can ask for, so a placer appends
/// launches forever in O(depth) memory (the streaming sequence path).
#[derive(Debug)]
struct Placer {
    /// MRU frees (end of the last stream).
    stream_end: u64,
    /// Last unit's completion (output ready: the data dependency).
    compute_end: u64,
    /// MMU frees (end of the last compute chain, excluding stream tails).
    mmu_free: u64,
    /// Completions of the last `cap` placed units, in order (the
    /// slot-release times the headroom gate can still reach).
    ce_hist: std::collections::VecDeque<u64>,
    /// Units placed since the last barrier (a prefetch deeper than this
    /// finds the buffer empty and streams immediately).
    placed: usize,
    /// Ring bound: must be ≥ every depth passed to [`Self::slot_free`].
    cap: usize,
}

impl Placer {
    fn new(cap: usize) -> Self {
        Placer {
            stream_end: 0,
            compute_end: 0,
            mmu_free: 0,
            ce_hist: std::collections::VecDeque::with_capacity(cap + 1),
            placed: 0,
            cap: cap.max(1),
        }
    }

    /// Release time of the weight-buffer slot a `depth`-deep prefetch
    /// would reuse: the completion of the unit `depth` places back.
    ///
    /// Approximation: the gate counts *units* against the current unit's
    /// per-stage depth, so across a stage transition (wider windows two
    /// units back, or the s3→s0 warm boundary) the modelled in-flight
    /// bytes can briefly exceed the weight buffer — the same
    /// unit-granularity abstraction PR 2's uniform two-deep gate used,
    /// now per-stage. Byte-accurate residency tracking is a ROADMAP
    /// item; it would perturb the calibrated single-launch totals.
    fn slot_free(&self, depth: usize) -> u64 {
        debug_assert!(depth <= self.cap, "depth {depth} outruns ring cap {}", self.cap);
        if self.placed >= depth {
            self.ce_hist[self.ce_hist.len() - depth]
        } else {
            0
        }
    }

    /// `not_before` gates the unit's *compute* on external input
    /// availability (a sharded pipeline's upstream link transfer); the
    /// weight stream is card-local prefetch and is never gated by it.
    /// `not_before = 0` is exactly the ungated recurrence, bit for bit.
    fn place(
        &mut self,
        unit: &UnitCost,
        replicas: u64,
        entry: Entry,
        depth: usize,
        not_before: u64,
    ) -> UnitSpan {
        let c = replicas * unit.compute;
        let (stream_start, compute_start) = match entry {
            Entry::Sequential => {
                let t = self.compute_end.max(not_before);
                (self.compute_end, t)
            }
            Entry::Pipelined { fill } => {
                let ss = self.stream_end.max(self.slot_free(depth));
                (ss, self.compute_end.max(ss + fill).max(not_before))
            }
            Entry::Warm { fill } => {
                let ss = self.stream_end.max(self.slot_free(depth));
                (ss, self.mmu_free.max(ss + fill).max(not_before))
            }
        };
        let stream_end = stream_start + unit.mem;
        let compute_end = (compute_start + c).max(stream_end);
        self.stream_end = stream_end;
        self.compute_end = compute_end;
        self.mmu_free = self.mmu_free.max(compute_start + c);
        self.ce_hist.push_back(compute_end);
        if self.ce_hist.len() > self.cap {
            self.ce_hist.pop_front();
        }
        self.placed += 1;
        UnitSpan {
            stream_start,
            stream_end,
            compute_start,
            compute_end,
        }
    }

    /// Hard launch boundary (`overlap_interlaunch = false`): nothing of
    /// the next launch may start before everything so far has drained.
    fn barrier(&mut self) {
        let t = self.compute_end.max(self.stream_end);
        self.stream_end = t;
        self.compute_end = t;
        self.mmu_free = t;
        self.ce_hist.clear();
        self.placed = 0;
    }

    /// The placer state normalized to `origin` (the end of the launch
    /// just placed): every frontier and reachable slot-release time as a
    /// backward offset. Two appends of the same batch from equal
    /// signatures place identically shifted — signature equality IS the
    /// fixed point [`PipelineSchedule::steady_launch_cycles`] detects.
    fn signature(&self, origin: u64) -> (usize, u64, u64, Vec<u64>) {
        (
            // saturated unit count: past `cap` placed units the
            // empty-buffer branch of `slot_free` is unreachable, so all
            // such states gate identically
            self.placed.min(self.cap),
            origin - self.stream_end,
            origin - self.mmu_free,
            self.ce_hist.iter().map(|&t| origin - t).collect(),
        )
    }
}

impl PipelineSchedule {
    /// Build the schedule for a variant: graph → priced units → IR. The
    /// buffer model is computed from `variant` directly, so custom
    /// (non-registry) variants get the same per-stage prefetch gating as
    /// registered ones.
    pub fn for_variant(variant: &SwinVariant, cfg: AccelConfig) -> Self {
        let graph = WorkloadGraph::build(variant);
        let scheduler = Scheduler::new(cfg);
        Self::lower_for(&graph, &scheduler, Some(variant))
    }

    /// Lower the scheduler's priced units into the event IR. The buffer
    /// model is resolved by the graph's variant *name*; prefer
    /// [`Self::for_variant`] for custom variants (an unknown name falls
    /// back to the pre-buffer-model two-deep, no-fill gate).
    pub fn lower(graph: &WorkloadGraph, scheduler: &Scheduler) -> Self {
        Self::lower_for(graph, scheduler, SwinVariant::by_name(graph.variant))
    }

    pub(crate) fn lower_for(
        graph: &WorkloadGraph,
        scheduler: &Scheduler,
        variant: Option<&SwinVariant>,
    ) -> Self {
        let sched_units = scheduler.schedule(graph);
        let mut ops = graph.ops.iter();
        let mut units = Vec::with_capacity(sched_units.len());
        for su in &sched_units {
            let mut unit = UnitCost {
                label: su.label.clone(),
                stage: su.stage,
                compute: su.compute() + su.nonlinear_exposed(),
                mem: su.mem(),
                mmu: su.compute(),
                scu: 0,
                gcu: 0,
                nonlinear_exposed: su.nonlinear_exposed(),
                ops: Vec::with_capacity(su.timings.len()),
            };
            for t in &su.timings {
                let op = ops.next().expect("schedule/graph op mismatch");
                let nl_unit = match op.op {
                    OpKind::Softmax { .. } => Resource::Scu,
                    _ => Resource::Gcu,
                };
                match nl_unit {
                    Resource::Scu => unit.scu += t.nonlinear_cycles,
                    _ => unit.gcu += t.nonlinear_cycles,
                }
                unit.ops.push(OpCost {
                    label: format!("{}:{}", su.label, kind_name(&op.op)),
                    compute: t.compute_cycles,
                    nonlinear: t.nonlinear_cycles,
                    nonlinear_exposed: t.nonlinear_exposed,
                    nl_unit,
                });
            }
            if scheduler.cfg.nl_design.design().shared_pipe() {
                arbitrate_shared_pipe(&mut unit);
            }
            units.push(unit);
        }
        // per-stage prefetch headroom + cold entry fill from the buffer
        // model (unresolvable variants fall back to the two-deep,
        // no-fill placement the IR used before the buffer model was
        // wired in)
        let (prefetch_depths, window_fills) = match variant {
            Some(v) => {
                let plan = BufferPlan::for_variant(v);
                let mem = MemoryModel::new(scheduler.cfg.clone());
                let fills: Vec<u64> = (0..v.num_stages())
                    .map(|s| mem.transfer_cycles(plan.stream_window_bytes(s) as u64))
                    .collect();
                (plan.prefetch_depths(), fills)
            }
            None => (vec![2], vec![0u64]),
        };
        let mut s = PipelineSchedule {
            variant: graph.variant,
            cfg: scheduler.cfg.clone(),
            units,
            prefetch_depths,
            window_fills,
            total_cycles: 0,
        };
        s.total_cycles = s.launch_cycles(1);
        s
    }

    /// Restrict the schedule to the units of stages `lo..hi` — the
    /// per-card schedule of one shard of a [`super::shard::ShardPlan`].
    /// Unit stage indices stay *global* (stage 2 of a `2..4` shard is
    /// still stage 2); prefetch depths and window fills come from the
    /// shard card's own [`BufferPlan::for_stage_range`] sizing (its
    /// weight buffer is a double window of its own widest hosted stage).
    /// The full range `0..num_stages()` is bit-identical to
    /// [`Self::for_variant`] — the single-shard lowering contract.
    pub fn for_variant_stages(
        variant: &SwinVariant,
        cfg: AccelConfig,
        lo: usize,
        hi: usize,
    ) -> Self {
        let ns = variant.num_stages();
        assert!(lo < hi && hi <= ns, "bad stage range {lo}..{hi}");
        let mut s = Self::for_variant(variant, cfg);
        if lo == 0 && hi == ns {
            return s; // BufferPlan::for_stage_range(0, ns) == for_variant
        }
        s.units.retain(|u| (lo..hi).contains(&u.stage));
        let plan = BufferPlan::for_stage_range(variant, lo, hi);
        let mem = MemoryModel::new(s.cfg.clone());
        // full-length per-stage vectors (units index by global stage);
        // un-hosted stages keep inert defaults no retained unit reaches
        let mut depths = vec![2usize; ns];
        let mut fills = vec![0u64; ns];
        for st in lo..hi {
            depths[st] = plan.prefetch_depth(st - lo);
            fills[st] = mem.transfer_cycles(plan.stream_window_bytes(st - lo) as u64);
        }
        s.prefetch_depths = depths;
        s.window_fills = fills;
        s.total_cycles = s.launch_cycles(1);
        s
    }

    /// Prefetch headroom of a stage (out-of-range clamps to the last).
    pub fn prefetch_depth(&self, stage: usize) -> usize {
        match self.prefetch_depths.get(stage) {
            Some(&d) => d,
            None => self.prefetch_depths.last().copied().unwrap_or(2),
        }
    }

    /// Cold entry fill of a unit: one stream window must land before a
    /// cold launch's compute may start — capped at the unit's own stream.
    fn entry_fill(&self, u: &UnitCost) -> u64 {
        let window = match self.window_fills.get(u.stage) {
            Some(&w) => w,
            None => self.window_fills.last().copied().unwrap_or(0),
        };
        u.mem.min(window)
    }

    /// Place one launch, continuing `p`'s timeline. `warm_boundary`
    /// marks a cross-launch entry with prefetch (no fill, MMU-free start).
    /// `input_ready` gates the launch's first compute on its input
    /// arriving (an upstream shard's link transfer; 0 = available now —
    /// later units chain off the first's completion, which already
    /// carries the gate transitively).
    fn place_launch(
        &self,
        p: &mut Placer,
        batch: usize,
        warm_boundary: bool,
        input_ready: u64,
    ) -> Vec<UnitSpan> {
        let b = batch.max(1) as u64;
        let mut spans = Vec::with_capacity(self.units.len());
        for (i, u) in self.units.iter().enumerate() {
            let depth = self.prefetch_depth(u.stage);
            let entry = if i == 0 && warm_boundary {
                // the window fill is a double-buffering (pipelined-mode)
                // concept; sequential-unit mode models no fills at all,
                // so a warm boundary must not charge one either — else a
                // warm sequence could exceed the barrier sequence
                Entry::Warm {
                    fill: if self.cfg.overlap_interunit {
                        self.entry_fill(u)
                    } else {
                        0
                    },
                }
            } else if self.cfg.overlap_interunit {
                Entry::Pipelined {
                    fill: if i == 0 { self.entry_fill(u) } else { 0 },
                }
            } else {
                Entry::Sequential
            };
            let gate = if i == 0 { input_ready } else { 0 };
            spans.push(p.place(u, b, entry, depth, gate));
        }
        spans
    }

    /// Ring bound for a placer driven by this schedule: the deepest
    /// per-stage prefetch the headroom gate can reach back.
    fn hist_cap(&self) -> usize {
        self.prefetch_depths.iter().copied().max().unwrap_or(2).max(2)
    }

    /// Place every unit on the launch timeline for a batch-`batch` launch.
    ///
    /// The recurrence (see module docs): unit *i*'s stream starts when
    /// the MRU frees and the weight buffer has a slot (the per-stage
    /// [`BufferPlan`] headroom) in pipelined mode, or at unit *i−1*'s
    /// completion in sequential mode; compute starts when the previous
    /// unit's output is ready but never before the unit's own stream
    /// begins (plus, for a cold launch entry, one window fill);
    /// completion waits for both compute and stream.
    pub fn placements(&self, batch: usize) -> Vec<UnitSpan> {
        let mut p = Placer::new(self.hist_cap());
        self.place_launch(&mut p, batch, false, 0)
    }

    /// Place a back-to-back launch sequence on one absolute timeline.
    /// With [`AccelConfig::overlap_interlaunch`] off, launches are
    /// barrier-separated and the total is exactly `Σ launch_cycles(bᵢ)`.
    /// Streaming form: [`SequencePlacer`] (this is a thin collector over
    /// it).
    pub fn sequence(&self, batches: &[usize]) -> SequenceSchedule {
        let mut sp = SequencePlacer::new(self);
        let launches: Vec<LaunchSpan> = batches.iter().map(|&b| sp.append(b)).collect();
        SequenceSchedule {
            variant: self.variant,
            overlap_interlaunch: self.cfg.overlap_interlaunch,
            total_cycles: launches.last().map_or(0, |l| l.end),
            launches,
        }
    }

    /// Total cycles of a launch sequence (see [`Self::sequence`]).
    pub fn sequence_cycles(&self, batches: &[usize]) -> u64 {
        self.sequence(batches).total_cycles
    }

    /// Steady-state (warm-queue) cost of one more batch-`batch` launch
    /// appended to an infinite back-to-back stream of equal launches:
    /// the converged per-launch increment of [`Self::sequence`]. Equals
    /// [`Self::launch_cycles`] when cross-launch prefetch is off; at most
    /// it otherwise (the warm entry skips the cold fill and starts
    /// compute at MMU-free).
    ///
    /// Computed by warm-**appending** onto one persistent placer —
    /// O(units) per append instead of re-placing the whole prefix — and
    /// converged on a true fixed point: the normalized placer state
    /// ([`Placer::signature`]) repeating exactly, which *guarantees*
    /// every later append costs the same increment. (The previous
    /// implementation re-placed `vec![batch; k]` for k ≤ 8 from scratch
    /// — O(k²·units) — and could exit the loop without converging,
    /// returning a still-transient increment.)
    pub fn steady_launch_cycles(&self, batch: usize) -> u64 {
        if !self.cfg.overlap_interlaunch {
            return self.launch_cycles(batch);
        }
        let mut sp = SequencePlacer::new(self);
        let mut prev_end = sp.append(batch).end; // the cold head launch
        let mut inc = prev_end;
        let mut prev_sig = sp.state_signature();
        // max-plus recurrence with an identical per-launch structure:
        // the state fixed point lands within a few warm appends. The cap
        // is a safety valve far above any observed transient (the old
        // loop allowed 8 launches total); hitting it returns the last
        // increment, exactly as the old loop's exhaustion path did.
        for _ in 0..64 {
            let end = sp.append(batch).end;
            let next = end - prev_end;
            let sig = sp.state_signature();
            if next == inc && sig == prev_sig {
                return inc;
            }
            inc = next;
            prev_end = end;
            prev_sig = sig;
        }
        inc
    }

    /// Modelled cycles for one launch of `batch` images: the weight
    /// stream is issued once, compute events replay per image.
    pub fn launch_cycles(&self, batch: usize) -> u64 {
        self.placements(batch).last().map_or(0, |s| s.compute_end)
    }

    /// Modelled service time of one launch of `batch` images.
    pub fn launch_ms(&self, batch: usize) -> f64 {
        self.cfg.cycles_to_ms(self.launch_cycles(batch))
    }

    /// Busy cycles of one resource over a single-image launch.
    pub fn busy(&self, r: Resource) -> u64 {
        self.units
            .iter()
            .map(|u| match r {
                Resource::Mru => u.mem,
                Resource::Mmu => u.mmu,
                Resource::Scu => u.scu,
                Resource::Gcu => u.gcu,
                // a single-card schedule owns no inter-card link
                Resource::Link => 0,
            })
            .sum()
    }

    /// Busy cycles of one resource over a batch-`batch` launch: compute
    /// engines (MMU/SCU/GCU) replay per image, the weight stream is
    /// issued once per launch. This is the per-launch activity vector
    /// the energy model integrates over a launch span
    /// ([`super::power::span_power_w`]).
    pub fn busy_batched(&self, r: Resource, batch: usize) -> u64 {
        match r {
            Resource::Mru => self.busy(r),
            _ => batch.max(1) as u64 * self.busy(r),
        }
    }

    /// Wake-up cost of a power-gated card: gating drops the resident
    /// weight window, so before the first launch computes, one stream
    /// window of the first unit must land again — the cold-entry fill of
    /// the sequence IR, applied at the card (not launch) level. The
    /// serving stack prices this into a gated card's first (cold) launch
    /// exactly like the PR-4 cold/warm split prices the entry fill.
    pub fn wakeup_fill_cycles(&self) -> u64 {
        self.units.first().map_or(0, |u| self.entry_fill(u))
    }

    /// Per-stage cycle totals: each unit contributes the timeline it
    /// *advances* (`compute_end(i) − compute_end(i−1)`), so the stage
    /// totals partition `launch_cycles(batch)` exactly — overlapped
    /// prefetch time is attributed to the unit that hides it.
    ///
    /// Panics if any unit's stage index is out of range: every op must
    /// carry an exact stage (no clamping — see `Simulator::aggregate`).
    pub fn stage_spans(&self, stages: usize, batch: usize) -> Vec<u64> {
        let mut out = vec![0u64; stages];
        let mut prev = 0u64;
        for (u, sp) in self.units.iter().zip(self.placements(batch)) {
            assert!(
                u.stage < stages,
                "unit {} carries stage {} outside 0..{stages}",
                u.label,
                u.stage
            );
            out[u.stage] += sp.compute_end - prev;
            prev = sp.compute_end;
        }
        out
    }

    /// Emit the event list of one placed launch into `segs`. `prefix`
    /// tags the labels (launch index in a sequence); each launch emits
    /// its *own* stream segments at its own spans — a later launch never
    /// re-emits an earlier launch's stream.
    pub(crate) fn emit_segments(
        &self,
        spans: &[UnitSpan],
        batch: usize,
        prefix: &str,
        segs: &mut Vec<Segment>,
    ) {
        for (u, sp) in self.units.iter().zip(spans) {
            if u.mem > 0 {
                segs.push(Segment {
                    unit: Resource::Mru,
                    label: format!("{prefix}{}:stream", u.label),
                    start: sp.stream_start,
                    end: sp.stream_end,
                });
            }
            let mut mmu_t = sp.compute_start;
            let mut nl_t = sp.compute_start;
            for _ in 0..batch.max(1) {
                for op in &u.ops {
                    if op.compute > 0 {
                        segs.push(Segment {
                            unit: Resource::Mmu,
                            label: format!("{prefix}{}", op.label),
                            start: mmu_t,
                            end: mmu_t + op.compute,
                        });
                        mmu_t += op.compute;
                    }
                    if op.nonlinear_exposed > 0 {
                        let start = mmu_t.max(nl_t);
                        segs.push(Segment {
                            unit: op.nl_unit,
                            label: format!("{prefix}{}", op.label),
                            start,
                            end: start + op.nonlinear.max(1),
                        });
                        nl_t = start + op.nonlinear_exposed;
                        mmu_t += op.nonlinear_exposed;
                    }
                }
            }
        }
    }

    /// The full event list of a batch-`batch` launch: one stream segment
    /// per unit plus per-op MMU/SCU/GCU segments per batch replica.
    /// Nonlinear segments carry their *full* engine occupancy (the SCU
    /// drains rows while the MMU moves on); only the fill is exposed on
    /// the compute chain.
    pub fn segments(&self, batch: usize) -> Vec<Segment> {
        let mut segs = Vec::new();
        self.emit_segments(&self.placements(batch), batch, "", &mut segs);
        segs
    }

    /// The full event list of a placed launch sequence: each launch's
    /// events at its absolute spans, labels prefixed `L<j>:`.
    pub fn sequence_segments(&self, seq: &SequenceSchedule) -> Vec<Segment> {
        let mut segs = Vec::new();
        for (j, l) in seq.launches.iter().enumerate() {
            self.emit_segments(&l.spans, l.batch, &format!("L{j}:"), &mut segs);
        }
        segs
    }

    /// Compact JSON summary for the metrics endpoint and reports.
    pub fn summary_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("variant".into(), Json::Str(self.variant.into()));
        obj.insert(
            "overlap_interunit".into(),
            Json::Bool(self.cfg.overlap_interunit),
        );
        obj.insert(
            "overlap_interlaunch".into(),
            Json::Bool(self.cfg.overlap_interlaunch),
        );
        obj.insert(
            "prefetch_depths".into(),
            Json::Arr(
                self.prefetch_depths
                    .iter()
                    .map(|&d| Json::Num(d as f64))
                    .collect(),
            ),
        );
        obj.insert("total_cycles".into(), Json::Num(self.total_cycles as f64));
        obj.insert(
            "latency_ms".into(),
            Json::Num(self.cfg.cycles_to_ms(self.total_cycles)),
        );
        let mut busy = std::collections::BTreeMap::new();
        for r in Resource::ALL {
            busy.insert(r.name().into(), Json::Num(self.busy(r) as f64));
        }
        obj.insert("busy_cycles".into(), Json::Obj(busy));
        let mut launches = std::collections::BTreeMap::new();
        let mut steady = std::collections::BTreeMap::new();
        for b in [1usize, 2, 4, 8] {
            launches.insert(b.to_string(), Json::Num(self.launch_cycles(b) as f64));
            steady.insert(
                b.to_string(),
                Json::Num(self.steady_launch_cycles(b) as f64),
            );
        }
        obj.insert("launch_cycles".into(), Json::Obj(launches));
        // the warm/cold split: steady-state (warm-queue) per-launch cost
        obj.insert("steady_launch_cycles".into(), Json::Obj(steady));
        Json::Obj(obj)
    }
}

/// Streaming launch-sequence placement: appends launches one at a time
/// onto one persistent [`Placer`], so a consumer walking an arbitrarily
/// long back-to-back queue (the steady-state convergence loop,
/// `trace --launches N`) pays O(units) per launch and O(prefetch-depth)
/// memory — never a re-placement of the prefix.
/// [`PipelineSchedule::sequence`] is a thin collector over this.
pub struct SequencePlacer<'a> {
    schedule: &'a PipelineSchedule,
    p: Placer,
    launches: usize,
    end: u64,
}

impl<'a> SequencePlacer<'a> {
    pub fn new(schedule: &'a PipelineSchedule) -> Self {
        SequencePlacer {
            p: Placer::new(schedule.hist_cap()),
            schedule,
            launches: 0,
            end: 0,
        }
    }

    /// Place the next launch of the sequence and return its absolute
    /// span. The first launch enters cold; followers enter warm when
    /// [`AccelConfig::overlap_interlaunch`] is on and behind a hard
    /// barrier otherwise (sequence total exactly `Σ launch_cycles(bᵢ)`).
    pub fn append(&mut self, batch: usize) -> LaunchSpan {
        self.append_gated(batch, 0)
    }

    /// [`Self::append`] with an input-availability gate: the launch's
    /// first compute may not start before `input_ready` (the arrival of
    /// an upstream shard's link transfer). The weight stream still
    /// prefetches ungated — weights are card-local. `append(b)` is
    /// exactly `append_gated(b, 0)`.
    pub fn append_gated(&mut self, batch: usize, input_ready: u64) -> LaunchSpan {
        if self.launches > 0 && !self.schedule.cfg.overlap_interlaunch {
            self.p.barrier();
        }
        let warm = self.launches > 0 && self.schedule.cfg.overlap_interlaunch;
        let spans = self
            .schedule
            .place_launch(&mut self.p, batch, warm, input_ready);
        self.launches += 1;
        self.end = spans.last().map_or(self.end, |s| s.compute_end);
        LaunchSpan {
            batch: batch.max(1),
            start: spans.first().map_or(self.end, |s| s.stream_start),
            end: self.end,
            spans,
        }
    }

    /// Launches appended so far.
    pub fn launches(&self) -> usize {
        self.launches
    }

    /// Completion of the last appended launch (0 before any append).
    pub fn total_cycles(&self) -> u64 {
        self.end
    }

    /// Normalized placer state (see [`Placer::signature`]); equal
    /// signatures across two appends of the same batch prove the
    /// sequence reached its steady state.
    pub(crate) fn state_signature(&self) -> (usize, u64, u64, Vec<u64>) {
        self.p.signature(self.end)
    }
}

// ---------------------------------------------------------------------------
// CostTable
// ---------------------------------------------------------------------------

/// Shared launch-cost table: the cold ([`PipelineSchedule::launch_cycles`])
/// and warm ([`PipelineSchedule::steady_launch_cycles`]) cycles of every
/// serving bucket, memoized once per (variant, [`AccelConfig`]).
///
/// This is the allocation-free hot-path contract of the serving stack:
/// an `Arc<CostTable>` is built **once** per variant in a fleet and
/// shared by every `SimEngine`, `ServicePrior` and router card of that
/// variant — N homogeneous cards lower the workload graph, place the
/// schedule and converge the warm steady state exactly once instead of
/// N times, and every per-arrival price is a table lookup instead of a
/// fresh O(units) placement.
///
/// Lookups outside the memoized buckets fall back to computing from the
/// schedule (documented cold path); [`CostTable::with_buckets`] extends
/// the table to an engine's actual bucket ladder up front.
#[derive(Debug, Clone)]
pub struct CostTable {
    schedule: Arc<PipelineSchedule>,
    /// `(batch, cold cycles, warm cycles)`, sorted by batch.
    entries: Vec<(usize, u64, u64)>,
}

impl CostTable {
    /// Build the table for `buckets` over an already-lowered schedule.
    pub fn from_schedule(schedule: PipelineSchedule, buckets: &[usize]) -> Self {
        Self::from_arc(Arc::new(schedule), buckets)
    }

    /// Build the table over a shared schedule (no re-lowering).
    pub fn from_arc(schedule: Arc<PipelineSchedule>, buckets: &[usize]) -> Self {
        let mut sizes: Vec<usize> = buckets.iter().map(|&b| b.max(1)).collect();
        sizes.sort_unstable();
        sizes.dedup();
        let entries = sizes
            .into_iter()
            .map(|b| (b, schedule.launch_cycles(b), schedule.steady_launch_cycles(b)))
            .collect();
        CostTable { schedule, entries }
    }

    /// Lower `variant` under `cfg` and memoize `buckets` — the one-stop
    /// constructor fleet builders share via `Arc::new`.
    pub fn for_variant(variant: &SwinVariant, cfg: AccelConfig, buckets: &[usize]) -> Self {
        Self::from_schedule(PipelineSchedule::for_variant(variant, cfg), buckets)
    }

    /// The underlying schedule (single timing source).
    pub fn schedule(&self) -> &PipelineSchedule {
        &self.schedule
    }

    /// Share the schedule itself (e.g. with a `VirtualDevice`).
    pub fn share_schedule(&self) -> Arc<PipelineSchedule> {
        Arc::clone(&self.schedule)
    }

    /// A copy of this table extended to also memoize `sizes` (shares the
    /// schedule; only missing buckets are computed).
    pub fn with_buckets(&self, sizes: &[usize]) -> Self {
        let mut t = self.clone();
        for &b in sizes {
            let b = b.max(1);
            if let Err(i) = t.entries.binary_search_by_key(&b, |e| e.0) {
                t.entries.insert(
                    i,
                    (b, t.schedule.launch_cycles(b), t.schedule.steady_launch_cycles(b)),
                );
            }
        }
        t
    }

    /// Memoized buckets, ascending.
    pub fn buckets(&self) -> impl Iterator<Item = usize> + '_ {
        self.entries.iter().map(|e| e.0)
    }

    /// Cold launch cycles of one batch-`batch` launch (table hit for
    /// memoized buckets; computed from the schedule otherwise).
    pub fn cold_cycles(&self, batch: usize) -> u64 {
        let b = batch.max(1);
        match self.entries.binary_search_by_key(&b, |e| e.0) {
            Ok(i) => self.entries[i].1,
            Err(_) => self.schedule.launch_cycles(b),
        }
    }

    /// Warm (steady-state) cycles of one batch-`batch` launch.
    pub fn warm_cycles(&self, batch: usize) -> u64 {
        let b = batch.max(1);
        match self.entries.binary_search_by_key(&b, |e| e.0) {
            Ok(i) => self.entries[i].2,
            Err(_) => self.schedule.steady_launch_cycles(b),
        }
    }

    /// Cold launch service time in milliseconds.
    pub fn cold_ms(&self, batch: usize) -> f64 {
        self.schedule.cfg.cycles_to_ms(self.cold_cycles(batch))
    }

    /// Warm launch service time in milliseconds.
    pub fn warm_ms(&self, batch: usize) -> f64 {
        self.schedule.cfg.cycles_to_ms(self.warm_cycles(batch))
    }
}

/// Shared-pipe arbitration for designs where one exp/normalise datapath
/// serves both the SCU and the GCU (QUARK-style circuit sharing,
/// [`super::nonlinear::NonlinearDesign::shared_pipe`]).
///
/// The designs price their ops at II = 1 — full pipe ownership. That is
/// correct whenever only one of softmax/GELU is live, which is every
/// window of the registry graphs (a block's softmax has fully drained
/// long before its GELU issues: attn·V, proj and mlp1 sit between them).
/// When the walk *does* find the other engine still draining at an op's
/// issue point, the shared pipe serialises: the op is charged the
/// contended cycles — `min(other-engine drain − issue, own occupancy)`,
/// i.e. at worst the flat II = 2 surcharge the pre-arbitration model
/// applied to every window — on both its occupancy and its exposed fill,
/// and the unit totals are updated so segment emission and busy
/// accounting stay consistent.
///
/// The walk replays the first batch replica of the unit (replicas repeat
/// the identical op pattern; drains that would spill a replica or unit
/// boundary are not carried — for every registry variant the inter-op
/// compute dwarfs the drains, so the approximation is exact there).
fn arbitrate_shared_pipe(unit: &mut UnitCost) {
    let mut mmu_t = 0u64;
    let mut nl_t = 0u64;
    let mut scu_drain = 0u64;
    let mut gcu_drain = 0u64;
    for op in &mut unit.ops {
        mmu_t += op.compute;
        if op.nonlinear_exposed > 0 {
            let start = mmu_t.max(nl_t);
            let other = match op.nl_unit {
                Resource::Scu => gcu_drain,
                _ => scu_drain,
            };
            let contended = other.saturating_sub(start).min(op.nonlinear);
            op.nonlinear += contended;
            op.nonlinear_exposed += contended;
            match op.nl_unit {
                Resource::Scu => {
                    unit.scu += contended;
                    scu_drain = start + op.nonlinear;
                }
                _ => {
                    unit.gcu += contended;
                    gcu_drain = start + op.nonlinear;
                }
            }
            unit.compute += contended;
            unit.nonlinear_exposed += contended;
            nl_t = start + op.nonlinear_exposed;
            mmu_t += op.nonlinear_exposed;
        }
    }
}

pub(crate) fn kind_name(op: &OpKind) -> &'static str {
    match op {
        OpKind::Gemm { kind, .. } => match kind {
            GemmKind::PatchEmbed => "patch_embed",
            GemmKind::Qkv => "qkv",
            GemmKind::Scores => "scores",
            GemmKind::AttnV => "attn_v",
            GemmKind::Proj => "proj",
            GemmKind::Mlp1 => "mlp1",
            GemmKind::Mlp2 => "mlp2",
            GemmKind::PatchMerge => "merge",
            GemmKind::Head => "head",
        },
        OpKind::Softmax { .. } => "softmax",
        OpKind::Gelu { .. } => "gelu",
        OpKind::Add { .. } => "add",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, MICRO, SMALL, TINY};

    fn schedule(v: &'static SwinVariant, cfg: AccelConfig) -> PipelineSchedule {
        PipelineSchedule::for_variant(v, cfg)
    }

    #[test]
    fn sequential_total_is_sum_of_unit_critical_paths() {
        let s = schedule(&TINY, AccelConfig::paper().sequential());
        let by_units: u64 = s.units.iter().map(|u| u.compute.max(u.mem)).sum();
        assert_eq!(s.total_cycles, by_units);
    }

    #[test]
    fn pipelined_never_slower_never_breaks_resource_bounds() {
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let pipe = schedule(v, AccelConfig::paper());
            let seq = schedule(v, AccelConfig::paper().sequential());
            assert!(pipe.total_cycles <= seq.total_cycles, "{}", v.name);
            // lower bounds: both serialized resource chains must fit
            let compute: u64 = pipe.units.iter().map(|u| u.compute).sum();
            assert!(pipe.total_cycles >= compute, "{}", v.name);
            assert!(pipe.total_cycles >= pipe.busy(Resource::Mru), "{}", v.name);
        }
    }

    #[test]
    fn prefetch_gains_on_tiny_are_modest_but_real() {
        // swin-t: pipelined 4 534 362 vs sequential 4 950 506 cycles. The
        // per-stage BufferPlan headroom (16/8/4/2 slots) lets early-stage
        // streams run well ahead of the compute chain, so the pipelined
        // launch hugs the MRU floor; the win stays bounded because the
        // workload is bandwidth-bound end to end.
        let pipe = schedule(&TINY, AccelConfig::paper());
        let seq = schedule(&TINY, AccelConfig::paper().sequential());
        assert!(pipe.total_cycles < seq.total_cycles);
        let gain = seq.total_cycles as f64 / pipe.total_cycles as f64;
        assert!((1.01..1.12).contains(&gain), "gain={gain}");
    }

    #[test]
    fn placements_are_causally_ordered() {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            let s = schedule(&TINY, cfg);
            let spans = s.placements(1);
            let mut prev_se = 0u64;
            let mut prev_ce = 0u64;
            for sp in &spans {
                assert!(sp.stream_start >= prev_se, "MRU serialises streams");
                assert!(sp.compute_start >= prev_ce, "MMU serialises compute");
                assert!(sp.compute_start >= sp.stream_start);
                assert!(sp.compute_end >= sp.stream_end);
                prev_se = sp.stream_end;
                prev_ce = sp.compute_end;
            }
            assert_eq!(spans.last().unwrap().compute_end, s.total_cycles);
        }
    }

    #[test]
    fn batch_replay_shares_the_weight_stream() {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            let s = schedule(&TINY, cfg);
            let c1 = s.launch_cycles(1);
            let c8 = s.launch_cycles(8);
            assert!(c8 < 8 * c1, "c1={c1} c8={c8}");
            assert!(c8 >= c1);
            // per-image cost never increases with batch
            let per = |b: usize| s.launch_cycles(b) as f64 / b as f64;
            assert!(per(2) <= per(1));
            assert!(per(4) <= per(2));
            assert!(per(8) <= per(4));
        }
    }

    #[test]
    fn segment_busy_matches_unit_totals() {
        let s = schedule(&MICRO, AccelConfig::paper());
        let segs = s.segments(1);
        for r in Resource::ALL {
            let seg_busy: u64 = segs.iter().filter(|e| e.unit == r).map(Segment::dur).sum();
            assert_eq!(seg_busy, s.busy(r), "{}", r.name());
        }
    }

    #[test]
    fn busy_batched_replays_compute_and_shares_the_stream() {
        let s = schedule(&TINY, AccelConfig::paper());
        for b in [1usize, 4, 8] {
            assert_eq!(s.busy_batched(Resource::Mru, b), s.busy(Resource::Mru));
            for r in [Resource::Mmu, Resource::Scu, Resource::Gcu] {
                assert_eq!(s.busy_batched(r, b), b as u64 * s.busy(r), "{}", r.name());
            }
        }
        assert_eq!(s.busy_batched(Resource::Mmu, 0), s.busy(Resource::Mmu));
    }

    #[test]
    fn wakeup_fill_is_the_first_units_entry_fill() {
        let s = schedule(&TINY, AccelConfig::paper());
        let first = &s.units[0];
        assert_eq!(s.wakeup_fill_cycles(), s.entry_fill(first));
        assert!(s.wakeup_fill_cycles() > 0);
        // the wake-up is one stream window, a small fraction of a launch
        assert!(s.wakeup_fill_cycles() < s.total_cycles / 10);
    }

    fn nl_op(unit: Resource, compute: u64, nonlinear: u64, exposed: u64) -> OpCost {
        OpCost {
            label: "synthetic".into(),
            compute,
            nonlinear,
            nonlinear_exposed: exposed,
            nl_unit: unit,
        }
    }

    fn synth_unit(ops: Vec<OpCost>) -> UnitCost {
        let mut u = UnitCost {
            label: "synth".into(),
            stage: 0,
            compute: 0,
            mem: 0,
            mmu: 0,
            scu: 0,
            gcu: 0,
            nonlinear_exposed: 0,
            ops,
        };
        for op in &u.ops {
            u.mmu += op.compute;
            u.nonlinear_exposed += op.nonlinear_exposed;
            match op.nl_unit {
                Resource::Scu => u.scu += op.nonlinear,
                _ => u.gcu += op.nonlinear,
            }
        }
        u.compute = u.mmu + u.nonlinear_exposed;
        u
    }

    #[test]
    fn shared_pipe_arbitration_is_a_no_op_when_one_engine_is_live() {
        // softmax drains fully before the gelu issues (large compute in
        // between): no contention, nothing charged
        let mut u = synth_unit(vec![
            nl_op(Resource::Scu, 10, 100, 30),
            nl_op(Resource::Gcu, 500, 40, 18),
        ]);
        let before = u.clone();
        arbitrate_shared_pipe(&mut u);
        assert_eq!(u.compute, before.compute);
        assert_eq!(u.scu, before.scu);
        assert_eq!(u.gcu, before.gcu);
        assert_eq!(u.nonlinear_exposed, before.nonlinear_exposed);
    }

    #[test]
    fn shared_pipe_arbitration_charges_only_the_contended_window() {
        // gelu issues at mmu_t = 10 + 30 + 5 = 45 while the scu still
        // drains until 10 + 100 = 110: contention = min(110 - 45, 40)
        let mut u = synth_unit(vec![
            nl_op(Resource::Scu, 10, 100, 30),
            nl_op(Resource::Gcu, 5, 40, 18),
        ]);
        let before = u.clone();
        arbitrate_shared_pipe(&mut u);
        let contended = 40; // capped at the op's own occupancy
        assert_eq!(u.gcu, before.gcu + contended);
        assert_eq!(u.scu, before.scu);
        assert_eq!(u.compute, before.compute + contended);
        assert_eq!(u.nonlinear_exposed, before.nonlinear_exposed + contended);
        // never worse than the flat II = 2 model it replaces
        assert!(u.gcu <= 2 * before.gcu && u.scu <= 2 * before.scu);

        // partial overlap: the gelu issues late enough that only part of
        // the scu drain contends
        let mut v = synth_unit(vec![
            nl_op(Resource::Scu, 10, 100, 30),
            nl_op(Resource::Gcu, 80, 40, 18),
        ]);
        let vb = v.clone();
        arbitrate_shared_pipe(&mut v);
        // issue at 10 + 30 + 80 = 120, after the scu drained at 110:
        // no contention left
        assert_eq!(v.gcu, vb.gcu);
        let mut w = synth_unit(vec![
            nl_op(Resource::Scu, 10, 100, 30),
            nl_op(Resource::Gcu, 50, 40, 18),
        ]);
        let wb = w.clone();
        arbitrate_shared_pipe(&mut w);
        // issue at 90, scu drains at 110: 20 contended cycles
        assert_eq!(w.gcu, wb.gcu + 20);
        assert_eq!(w.compute, wb.compute + 20);
    }

    #[test]
    fn quark_lowering_matches_baseline_when_engines_never_co_live() {
        // the registry graphs keep attn·V + proj + mlp1 between a
        // block's softmax and its gelu — the shared pipe is never
        // contended, so the arbitrated QUARK lowering prices exactly the
        // baseline cycles (the bug this fixes: the old model charged a
        // flat II = 2 on every window regardless)
        use crate::accel::nonlinear::NlDesign;
        for v in [&TINY, &SMALL] {
            let base = schedule(v, AccelConfig::paper());
            let quark = schedule(v, AccelConfig::paper().nonlinear(NlDesign::Quark));
            assert_eq!(quark.total_cycles, base.total_cycles, "{}", v.name);
            for r in Resource::ALL {
                assert_eq!(quark.busy(r), base.busy(r), "{} {}", v.name, r.name());
            }
            for b in [1usize, 8] {
                assert_eq!(quark.launch_cycles(b), base.launch_cycles(b), "{}", v.name);
                assert_eq!(
                    quark.steady_launch_cycles(b),
                    base.steady_launch_cycles(b),
                    "{}",
                    v.name
                );
            }
        }
    }

    #[test]
    fn segments_stay_inside_the_launch_window() {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            let s = schedule(&MICRO, cfg);
            for e in s.segments(1) {
                assert!(e.end >= e.start);
                assert!(e.end <= s.total_cycles, "{} overruns", e.label);
            }
        }
    }

    #[test]
    fn stage_spans_partition_the_total() {
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let s = schedule(v, AccelConfig::paper());
            let stages = v.num_stages();
            for b in [1usize, 4] {
                let spans = s.stage_spans(stages, b);
                assert_eq!(spans.iter().sum::<u64>(), s.launch_cycles(b), "{}", v.name);
            }
        }
    }

    #[test]
    fn summary_json_roundtrips() {
        let s = schedule(&MICRO, AccelConfig::paper());
        let j = Json::parse(&s.summary_json().to_string()).unwrap();
        assert_eq!(j.get("variant").unwrap().as_str(), Some("swin-micro"));
        assert_eq!(
            j.get("total_cycles").unwrap().as_usize().unwrap() as u64,
            s.total_cycles
        );
        assert!(j.get("busy_cycles").unwrap().get("MMU").is_some());
        assert!(j.get("launch_cycles").unwrap().get("8").is_some());
        assert!(j.get("steady_launch_cycles").unwrap().get("8").is_some());
        assert_eq!(
            j.get("overlap_interlaunch").unwrap().as_bool(),
            Some(true)
        );
        assert_eq!(
            j.get("prefetch_depths").unwrap().as_arr().unwrap().len(),
            MICRO.num_stages()
        );
    }

    #[test]
    fn prefetch_depths_come_from_the_buffer_plan() {
        use crate::accel::buffers::BufferPlan;
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let s = schedule(v, AccelConfig::paper());
            assert_eq!(
                s.prefetch_depths,
                BufferPlan::for_variant(v).prefetch_depths(),
                "{}",
                v.name
            );
        }
        // a custom (non-registry) variant gets the same plan-derived
        // gating through for_variant, not the legacy two-deep fallback
        let probe = SwinVariant {
            name: "probe",
            ..MICRO.clone()
        };
        let s = PipelineSchedule::for_variant(&probe, AccelConfig::paper());
        assert_eq!(s.prefetch_depths, BufferPlan::for_variant(&probe).prefetch_depths());
        assert_eq!(s.prefetch_depths.len(), probe.num_stages());
    }

    #[test]
    fn barrier_sequence_is_exactly_the_sum_of_single_launches() {
        for v in [&MICRO, &TINY] {
            for cfg in [
                AccelConfig::paper().interlaunch(false),
                AccelConfig::paper().sequential(),
            ] {
                let s = schedule(v, cfg);
                for batches in [vec![1usize], vec![8, 1], vec![4, 4, 8], vec![1, 2, 4, 8]] {
                    let want: u64 = batches.iter().map(|&b| s.launch_cycles(b)).sum();
                    assert_eq!(s.sequence_cycles(&batches), want, "{} {batches:?}", v.name);
                }
            }
        }
    }

    #[test]
    fn pipelined_sequence_never_slower_and_first_launch_is_cold() {
        for v in [&MICRO, &TINY] {
            let warm = schedule(v, AccelConfig::paper());
            let cold = schedule(v, AccelConfig::paper().interlaunch(false));
            for batches in [vec![1usize, 1, 1], vec![8, 8, 8, 8], vec![2, 8, 1]] {
                assert!(
                    warm.sequence_cycles(&batches) <= cold.sequence_cycles(&batches),
                    "{} {batches:?}",
                    v.name
                );
            }
            // a one-launch sequence is exactly the single-launch placement
            for b in [1usize, 8] {
                assert_eq!(warm.sequence_cycles(&[b]), warm.launch_cycles(b), "{}", v.name);
            }
        }
    }

    #[test]
    fn steady_cost_below_cold_when_warm_and_equal_when_disabled() {
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let warm = schedule(v, AccelConfig::paper());
            let cold = schedule(v, AccelConfig::paper().interlaunch(false));
            for b in [1usize, 2, 4, 8] {
                assert!(
                    warm.steady_launch_cycles(b) <= warm.launch_cycles(b),
                    "{} b={b}",
                    v.name
                );
                assert_eq!(cold.steady_launch_cycles(b), cold.launch_cycles(b));
            }
            // at batch 8 the warm queue strictly beats the cold launch
            // (the warm entry skips the cold window fill)
            assert!(
                warm.steady_launch_cycles(8) < warm.launch_cycles(8),
                "{}: warm {} !< cold {}",
                v.name,
                warm.steady_launch_cycles(8),
                warm.launch_cycles(8)
            );
        }
    }

    #[test]
    fn sequence_placer_streams_the_same_placement() {
        // the streaming appender and the collected sequence are the same
        // code path; pin the equivalence anyway (spans, starts, ends)
        for cfg in [
            AccelConfig::paper(),
            AccelConfig::paper().interlaunch(false),
            AccelConfig::paper().sequential(),
        ] {
            let s = schedule(&MICRO, cfg);
            let batches = [1usize, 8, 2, 4, 8];
            let seq = s.sequence(&batches);
            let mut sp = SequencePlacer::new(&s);
            for (j, &b) in batches.iter().enumerate() {
                let l = sp.append(b);
                assert_eq!(l.batch, seq.launches[j].batch);
                assert_eq!(l.start, seq.launches[j].start);
                assert_eq!(l.end, seq.launches[j].end);
                assert_eq!(sp.launches(), j + 1);
                assert_eq!(sp.total_cycles(), seq.launches[j].end);
            }
            assert_eq!(sp.total_cycles(), seq.total_cycles);
        }
    }

    // NOTE: the steady-increment fixed-point regression (stability under
    // further appended launches, every variant × bucket × flag) lives in
    // the integration suite — rust/tests/hotpath_equivalence.rs — which
    // also covers the engine/prior consumers; no in-module duplicate.

    #[test]
    fn cost_table_matches_the_schedule_bit_for_bit() {
        for v in [&MICRO, &TINY] {
            for cfg in [AccelConfig::paper(), AccelConfig::paper().interlaunch(false)] {
                let s = schedule(v, cfg.clone());
                let t = CostTable::for_variant(v, cfg, &[8, 4, 2, 1]);
                for b in [1usize, 2, 4, 8] {
                    assert_eq!(t.cold_cycles(b), s.launch_cycles(b), "{} b={b}", v.name);
                    assert_eq!(t.warm_cycles(b), s.steady_launch_cycles(b), "{} b={b}", v.name);
                }
                // a non-memoized bucket falls back to the schedule…
                assert_eq!(t.cold_cycles(3), s.launch_cycles(3));
                assert_eq!(t.warm_cycles(3), s.steady_launch_cycles(3));
                // …and with_buckets memoizes it without re-lowering
                let t2 = t.with_buckets(&[3]);
                assert_eq!(t2.cold_cycles(3), s.launch_cycles(3));
                assert!(t2.buckets().any(|b| b == 3));
                assert!(Arc::ptr_eq(&t.share_schedule(), &t2.share_schedule()));
            }
        }
    }

    #[test]
    fn cost_table_sharing_reuses_one_schedule() {
        let t = Arc::new(CostTable::for_variant(&MICRO, AccelConfig::paper(), &[8, 1]));
        let a = t.share_schedule();
        let b = t.share_schedule();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(t.schedule().variant, "swin-micro");
        // ms views agree with the cycle views
        let cfg = &t.schedule().cfg;
        assert_eq!(t.cold_ms(8), cfg.cycles_to_ms(t.cold_cycles(8)));
        assert_eq!(t.warm_ms(8), cfg.cycles_to_ms(t.warm_cycles(8)));
    }

    #[test]
    fn sequence_segments_emit_each_launch_once() {
        // regression: launches 1..N must emit their *own* stream
        // segments at their own offsets, never re-emitting launch 0's
        let s = schedule(&MICRO, AccelConfig::paper());
        let single_mru = s
            .segments(1)
            .iter()
            .filter(|e| e.unit == Resource::Mru)
            .count();
        let seq = s.sequence(&[1, 1, 1]);
        let segs = s.sequence_segments(&seq);
        let mru: Vec<&Segment> = segs.iter().filter(|e| e.unit == Resource::Mru).collect();
        assert_eq!(mru.len(), 3 * single_mru);
        // each launch's share is labelled and strictly later than the
        // previous launch's matching segment
        for k in 0..single_mru {
            let (a, b, c) = (&mru[k], &mru[single_mru + k], &mru[2 * single_mru + k]);
            assert!(a.label.starts_with("L0:"));
            assert!(b.label.starts_with("L1:"));
            assert!(c.label.starts_with("L2:"));
            assert!(a.start < b.start && b.start < c.start);
        }
        // segments stay inside the sequence window
        for e in &segs {
            assert!(e.end <= seq.total_cycles, "{} overruns", e.label);
        }
    }
}
