//! Power model → paper Table V / Fig. 12.
//!
//! `P = P_static + Σ_module (module resources × activity × per-resource
//! dynamic coefficient)`, the standard FPGA early-estimation form (the
//! paper used Vivado Report Power, which does the same with per-net
//! toggle data). Unlike the pre-refactor model, activity is **measured**:
//! each module's busy fraction comes from the pipeline schedule via
//! [`Activity::from_sim`] — the MMU's busy-interval fraction, the
//! SCU/GCU busy intervals (design-dependent: QUARK's serialised pipe is
//! busy longer, PEANO's shorter pipe less) and the MRU's streaming
//! fraction. Coefficients are calibrated to the paper's reported 10.69 W
//! (T/S) and 11.11 W (B) at 200 MHz; the *shape* — FPGA ≈ 10 W vs CPU
//! 120 W vs GPU 240 W — drives Fig. 12's energy-efficiency claims.

use crate::model::config::SwinVariant;

use super::resources::{
    buffer_resources, gcu_resources, infra_resources, mmu_resources, scu_resources, Resources,
};
use super::sim::SimResult;
use super::AccelConfig;

/// Static (leakage + PS-side) power of the ZU19EG platform, watts.
pub const P_STATIC_W: f64 = 4.0;

/// Dynamic power coefficients at 200 MHz, watts per used resource at
/// 100% activity.
pub const W_PER_DSP: f64 = 3.4e-3;
pub const W_PER_KLUT: f64 = 6.0e-3;
pub const W_PER_KFF: f64 = 1.4e-3;
pub const W_PER_BRAM: f64 = 3.6e-3;
/// DDR interface power per GB/s of sustained traffic.
pub const W_PER_GBPS: f64 = 0.30;

/// Clock-tree + idle toggling floor: a clocked-but-stalled module still
/// draws this fraction of its full-activity dynamic power.
pub const IDLE_ACTIVITY: f64 = 0.30;
/// MMU data-toggle derate: MAC operand streams toggle fewer nets per
/// cycle than the worst case the coefficients are normalised to.
pub const MMU_TOGGLE: f64 = 0.70;
/// Infrastructure (control/AXI/DSU) runs at a flat duty cycle.
pub const INFRA_ACTIVITY: f64 = 0.5;

/// busy fraction → effective activity: idle floor + busy-proportional.
fn eff(busy: f64) -> f64 {
    IDLE_ACTIVITY + (1.0 - IDLE_ACTIVITY) * busy.clamp(0.0, 1.0)
}

/// Dynamic power of one module's resource vector at a given activity.
fn module_w(r: Resources, activity: f64) -> f64 {
    (r.dsp as f64 * W_PER_DSP
        + r.lut as f64 / 1e3 * W_PER_KLUT
        + r.ff as f64 / 1e3 * W_PER_KFF
        + r.bram as f64 * W_PER_BRAM)
        * activity
}

/// Per-unit busy fractions over a run — the measured utilisation that
/// drives the power estimate (satellite: no more assumed constants).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Activity {
    /// MMU busy fraction (cycles with an active GEMM tile / total —
    /// the schedule's busy intervals, like every other unit; MAC-level
    /// efficiency inside those intervals is [`MMU_TOGGLE`]'s job).
    pub mmu: f64,
    /// SCU busy fraction (softmax busy intervals / total).
    pub scu: f64,
    /// GCU busy fraction.
    pub gcu: f64,
    /// MRU/buffer streaming fraction (external-memory busy / total).
    pub mru: f64,
}

impl Activity {
    /// Extract real per-unit utilisation from a simulated run. Each
    /// fraction is that unit's busy cycles over the run's total cycles,
    /// clamped to [0, 1] (overlapped units can book more busy cycles
    /// than the wall clock under double buffering).
    pub fn from_sim(sim: &SimResult) -> Activity {
        let total = sim.total_cycles.max(1) as f64;
        let frac = |busy: u64| (busy as f64 / total).clamp(0.0, 1.0);
        Activity {
            mmu: frac(sim.mmu_cycles),
            scu: frac(sim.scu_cycles),
            gcu: frac(sim.gcu_cycles),
            mru: frac(sim.mem_cycles),
        }
    }
}

/// The shared power expression: static + per-module dynamic at the
/// given activities + DDR traffic over `mem_cycles` of streaming inside
/// a `total_cycles` window. Both the whole-run estimate
/// ([`accelerator_power_w`]) and the per-launch-span estimate
/// ([`span_power_w`]) evaluate exactly this, so the two can never
/// drift apart term by term.
fn power_terms(
    v: &SwinVariant,
    cfg: &AccelConfig,
    act: Activity,
    mem_cycles: u64,
    total_cycles: u64,
) -> f64 {
    let p_mmu = module_w(mmu_resources(cfg), MMU_TOGGLE * eff(act.mmu));
    let p_scu = module_w(scu_resources(cfg), eff(act.scu));
    let p_gcu = module_w(gcu_resources(cfg), eff(act.gcu));
    let p_infra = module_w(infra_resources(v), INFRA_ACTIVITY);
    let p_bufs = module_w(buffer_resources(v), eff(act.mru));
    let traffic_gbps = (mem_cycles as f64 * cfg.effective_bw())
        / (total_cycles as f64 / (cfg.freq_mhz * 1e6))
        / 1e9;
    let p_ddr = traffic_gbps * W_PER_GBPS;
    P_STATIC_W + p_mmu + p_scu + p_gcu + p_infra + p_bufs + p_ddr
}

/// Estimate accelerator power for a variant given its simulated run.
/// Module-decomposed: each unit is priced at its own measured activity,
/// so a design that shrinks the GCU *and* keeps it idle longer (PEANO)
/// saves twice, while QUARK's cheaper fabric is partly clawed back by
/// its longer busy intervals.
pub fn accelerator_power_w(
    v: &SwinVariant,
    cfg: &AccelConfig,
    sim: &SimResult,
    act: Activity,
) -> f64 {
    power_terms(v, cfg, act, sim.mem_cycles, sim.total_cycles)
}

/// Per-unit busy cycles booked inside one launch span — the
/// launch-level counterpart of a run's [`SimResult`] busy fields. For a
/// batch-`b` launch the compute units replay per image (b× the
/// single-image busy cycles) while the weight stream runs once
/// ([`crate::accel::pipeline::PipelineSchedule::busy_batched`] builds
/// exactly this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanBusy {
    pub mmu: u64,
    pub scu: u64,
    pub gcu: u64,
    pub mru: u64,
}

/// Average power over one launch span: the busy-fraction-weighted
/// dynamic terms plus static, with DDR traffic taken from the span's
/// own streaming cycles. A *warm* launch books the same busy cycles
/// into a shorter span than a cold one, so it draws more watts but
/// strictly fewer joules — the shape the router's J/inference prices
/// inherit.
pub fn span_power_w(v: &SwinVariant, cfg: &AccelConfig, busy: SpanBusy, span_cycles: u64) -> f64 {
    let span = span_cycles.max(1);
    let total = span as f64;
    let frac = |b: u64| (b as f64 / total).clamp(0.0, 1.0);
    let act = Activity {
        mmu: frac(busy.mmu),
        scu: frac(busy.scu),
        gcu: frac(busy.gcu),
        mru: frac(busy.mru),
    };
    power_terms(v, cfg, act, busy.mru, span)
}

/// Energy of one launch: [`span_power_w`] watts × the span in seconds.
pub fn launch_energy_j(
    v: &SwinVariant,
    cfg: &AccelConfig,
    busy: SpanBusy,
    span_cycles: u64,
) -> f64 {
    span_power_w(v, cfg, busy, span_cycles) * (span_cycles as f64 / (cfg.freq_mhz * 1e6))
}

/// [`launch_energy_j`] in integer microjoules — the unit the router's
/// `u64` price snapshots carry (a ~10 W × 100 ms launch is ~1e6 µJ, so
/// rounding error is parts-per-million).
pub fn launch_energy_uj(
    v: &SwinVariant,
    cfg: &AccelConfig,
    busy: SpanBusy,
    span_cycles: u64,
) -> u64 {
    (launch_energy_j(v, cfg, busy, span_cycles) * 1e6).round() as u64
}

/// Idle (clocked but not gated) draw in integer microwatts: every unit
/// at the [`IDLE_ACTIVITY`] floor, no DDR traffic. This is what an
/// ungated idle card burns between launches — and what power gating
/// reclaims (a gated card is modelled at ~0 W, paying the wake-up fill
/// on its next cold launch instead).
pub fn idle_power_uw(v: &SwinVariant, cfg: &AccelConfig) -> u64 {
    let idle = Activity { mmu: 0.0, scu: 0.0, gcu: 0.0, mru: 0.0 };
    (power_terms(v, cfg, idle, 0, 1) * 1e6).round() as u64
}

/// FPS per watt — the paper's energy-efficiency metric (Fig. 12).
pub fn energy_efficiency(fps: f64, power_w: f64) -> f64 {
    fps / power_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::sim::Simulator;
    use crate::model::config::{BASE, SMALL, TINY};

    fn power_of(v: &'static SwinVariant) -> f64 {
        let cfg = AccelConfig::paper();
        let sim = Simulator::new(v, cfg.clone()).simulate_inference();
        accelerator_power_w(v, &cfg, &sim, Activity::from_sim(&sim))
    }

    #[test]
    fn tiny_small_power_near_paper() {
        // paper Table V: 10.69 W for Swin-T and Swin-S
        for v in [&TINY, &SMALL] {
            let p = power_of(v);
            assert!((p - 10.69).abs() < 1.2, "{}: {p} W", v.name);
        }
    }

    #[test]
    fn base_draws_more() {
        // paper: Swin-B 11.11 W > 10.69 W
        let pb = power_of(&BASE);
        let pt = power_of(&TINY);
        assert!(pb > pt, "base={pb} tiny={pt}");
        assert!((pb - 11.11).abs() < 1.3, "base={pb}");
    }

    #[test]
    fn activity_is_measured_not_assumed() {
        let cfg = AccelConfig::paper();
        let sim = Simulator::new(&TINY, cfg).simulate_inference();
        let a = Activity::from_sim(&sim);
        // memory-bound design: MRU streams nearly always, MMU waits on
        // it part of the time, nonlinear units are tiny slivers
        assert!(a.mru > 0.9, "mru={}", a.mru);
        assert!(a.mmu > 0.5 && a.mmu < 0.9, "mmu={}", a.mmu);
        assert!(a.scu > 0.0 && a.scu < 0.05, "scu={}", a.scu);
        assert!(a.gcu > 0.0 && a.gcu < 0.07, "gcu={}", a.gcu);
        for f in [a.mmu, a.scu, a.gcu, a.mru] {
            assert!((0.0..=1.0).contains(&f));
        }
    }

    #[test]
    fn busier_units_draw_more() {
        // same resources, higher activity → strictly more watts
        let cfg = AccelConfig::paper();
        let sim = Simulator::new(&TINY, cfg.clone()).simulate_inference();
        let a = Activity::from_sim(&sim);
        let hot = Activity {
            scu: (a.scu * 10.0).min(1.0),
            gcu: (a.gcu * 10.0).min(1.0),
            ..a
        };
        assert!(
            accelerator_power_w(&TINY, &cfg, &sim, hot)
                > accelerator_power_w(&TINY, &cfg, &sim, a)
        );
    }

    #[test]
    fn order_of_magnitude_vs_cpu_gpu() {
        // the whole point of Fig. 12: ~10 W vs 120 W (CPU) vs 240 W (GPU)
        let p = power_of(&TINY);
        assert!(p > 5.0 && p < 20.0, "p={p}");
    }

    #[test]
    fn efficiency_metric() {
        assert!((energy_efficiency(48.1, 10.69) - 4.5).abs() < 0.01);
    }

    #[test]
    fn span_power_over_the_whole_run_matches_the_run_estimate() {
        // feeding a full run's busy cycles and total span into the
        // span-power path must reproduce accelerator_power_w exactly —
        // both evaluate the same power_terms
        let cfg = AccelConfig::paper();
        for v in [&TINY, &SMALL, &BASE] {
            let sim = Simulator::new(v, cfg.clone()).simulate_inference();
            let busy = SpanBusy {
                mmu: sim.mmu_cycles,
                scu: sim.scu_cycles,
                gcu: sim.gcu_cycles,
                mru: sim.mem_cycles,
            };
            let a = accelerator_power_w(v, &cfg, &sim, Activity::from_sim(&sim));
            let b = span_power_w(v, &cfg, busy, sim.total_cycles);
            assert_eq!(a.to_bits(), b.to_bits(), "{}", v.name);
        }
    }

    #[test]
    fn warm_spans_draw_more_watts_but_fewer_joules() {
        // same work squeezed into a shorter (warm) span: higher average
        // activity → more watts, but less static/idle time → less energy
        let cfg = AccelConfig::paper();
        let sim = Simulator::new(&TINY, cfg.clone()).simulate_inference();
        let busy = SpanBusy {
            mmu: sim.mmu_cycles,
            scu: sim.scu_cycles,
            gcu: sim.gcu_cycles,
            mru: sim.mem_cycles,
        };
        let cold_span = sim.total_cycles;
        let warm_span = sim.total_cycles * 9 / 10;
        assert!(
            span_power_w(&TINY, &cfg, busy, warm_span)
                > span_power_w(&TINY, &cfg, busy, cold_span)
        );
        assert!(
            launch_energy_j(&TINY, &cfg, busy, warm_span)
                < launch_energy_j(&TINY, &cfg, busy, cold_span)
        );
    }

    #[test]
    fn idle_power_sits_between_zero_and_a_loaded_card() {
        let cfg = AccelConfig::paper();
        let idle_w = idle_power_uw(&TINY, &cfg) as f64 / 1e6;
        // at least the static floor, clearly below the loaded estimate
        assert!(idle_w >= P_STATIC_W, "idle={idle_w}");
        assert!(idle_w < power_of(&TINY), "idle={idle_w}");
    }

    #[test]
    fn microjoule_snapshot_matches_the_float_energy() {
        let cfg = AccelConfig::paper();
        let sim = Simulator::new(&TINY, cfg.clone()).simulate_inference();
        let busy = SpanBusy {
            mmu: sim.mmu_cycles,
            scu: sim.scu_cycles,
            gcu: sim.gcu_cycles,
            mru: sim.mem_cycles,
        };
        let j = launch_energy_j(&TINY, &cfg, busy, sim.total_cycles);
        let uj = launch_energy_uj(&TINY, &cfg, busy, sim.total_cycles);
        assert!((uj as f64 - j * 1e6).abs() <= 0.5);
        // sanity scale: one Swin-T inference at ~10 W × ~20 ms ≈ 0.2 J
        assert!(uj > 10_000 && uj < 10_000_000, "uj={uj}");
    }
}
