//! Power model → paper Table V / Fig. 12.
//!
//! `P = P_static + Σ (unit activity × per-resource dynamic coefficient)`,
//! the standard FPGA early-estimation form (the paper used Vivado Report
//! Power, which does the same with per-net toggle data). Coefficients are
//! calibrated to the paper's reported 10.69 W (T/S) and 11.11 W (B) at
//! 200 MHz; the *shape* — FPGA ≈ 10 W vs CPU 120 W vs GPU 240 W — drives
//! Fig. 12's energy-efficiency claims.

use crate::model::config::SwinVariant;

use super::resources::{accelerator_resources, Resources};
use super::sim::SimResult;
use super::AccelConfig;

/// Static (leakage + PS-side) power of the ZU19EG platform, watts.
pub const P_STATIC_W: f64 = 4.0;

/// Dynamic power coefficients at 200 MHz, watts per used resource at
/// 100% activity.
pub const W_PER_DSP: f64 = 3.4e-3;
pub const W_PER_KLUT: f64 = 6.0e-3;
pub const W_PER_KFF: f64 = 1.4e-3;
pub const W_PER_BRAM: f64 = 3.6e-3;
/// DDR interface power per GB/s of sustained traffic.
pub const W_PER_GBPS: f64 = 0.30;

/// Average activity factors by unit class while the accelerator runs.
#[derive(Debug, Clone, Copy)]
pub struct Activity {
    pub mmu: f64,
    pub logic: f64,
    pub bram: f64,
}

impl Default for Activity {
    fn default() -> Self {
        // memory-bound design: the MMU idles while weights stream
        Activity {
            mmu: 0.62,
            logic: 0.5,
            bram: 0.7,
        }
    }
}

/// Estimate accelerator power for a variant given its simulated run.
pub fn accelerator_power_w(
    v: &SwinVariant,
    cfg: &AccelConfig,
    sim: &SimResult,
    act: Activity,
) -> f64 {
    let r: Resources = accelerator_resources(v, cfg);
    let util = sim.mmu_utilization().clamp(0.0, 1.0);
    let mmu_act = act.mmu * (0.5 + 0.5 * util / 0.6); // scale with sustained MACs
    let dyn_dsp = r.dsp as f64 * W_PER_DSP * mmu_act;
    let dyn_lut = r.lut as f64 / 1e3 * W_PER_KLUT * act.logic;
    let dyn_ff = r.ff as f64 / 1e3 * W_PER_KFF * act.logic;
    let dyn_bram = r.bram as f64 * W_PER_BRAM * act.bram;
    let traffic_gbps = (sim.mem_cycles as f64 * cfg.effective_bw())
        / (sim.total_cycles as f64 / (cfg.freq_mhz * 1e6))
        / 1e9;
    let dyn_ddr = traffic_gbps * W_PER_GBPS;
    P_STATIC_W + dyn_dsp + dyn_lut + dyn_ff + dyn_bram + dyn_ddr
}

/// FPS per watt — the paper's energy-efficiency metric (Fig. 12).
pub fn energy_efficiency(fps: f64, power_w: f64) -> f64 {
    fps / power_w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::sim::Simulator;
    use crate::model::config::{BASE, SMALL, TINY};

    fn power_of(v: &'static SwinVariant) -> f64 {
        let cfg = AccelConfig::paper();
        let sim = Simulator::new(v, cfg.clone()).simulate_inference();
        accelerator_power_w(v, &cfg, &sim, Activity::default())
    }

    #[test]
    fn tiny_small_power_near_paper() {
        // paper Table V: 10.69 W for Swin-T and Swin-S
        for v in [&TINY, &SMALL] {
            let p = power_of(v);
            assert!((p - 10.69).abs() < 1.2, "{}: {p} W", v.name);
        }
    }

    #[test]
    fn base_draws_more() {
        // paper: Swin-B 11.11 W > 10.69 W
        let pb = power_of(&BASE);
        let pt = power_of(&TINY);
        assert!(pb > pt, "base={pb} tiny={pt}");
        assert!((pb - 11.11).abs() < 1.3, "base={pb}");
    }

    #[test]
    fn order_of_magnitude_vs_cpu_gpu() {
        // the whole point of Fig. 12: ~10 W vs 120 W (CPU) vs 240 W (GPU)
        let p = power_of(&TINY);
        assert!(p > 5.0 && p < 20.0, "p={p}");
    }

    #[test]
    fn efficiency_metric() {
        assert!((energy_efficiency(48.1, 10.69) - 4.5).abs() < 0.01);
    }
}
