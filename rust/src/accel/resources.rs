//! FPGA resource model → paper Tables III & IV.
//!
//! DSP counts derive from the architecture (32 PE × 49 mult = 1568; SCU
//! 49 lanes; GCU 2 EU × 49 = 98). LUT/FF costs use per-element unit costs
//! calibrated once against Table III (the paper's Vivado synthesis — we
//! have no synthesiser, see DESIGN.md §5.1); totals then follow
//! structurally for Table IV, including the Swin-B deltas.

use crate::model::config::SwinVariant;

use super::buffers::BufferPlan;
use super::AccelConfig;

/// The Xilinx XCZU19EG (paper §V.D).
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub name: &'static str,
    pub luts: u32,
    pub ffs: u32,
    pub dsps: u32,
    pub bram36: u32,
}

pub const XCZU19EG: Device = Device {
    name: "XCZU19EG",
    luts: 522_720,
    ffs: 1_045_440,
    dsps: 1968,
    bram36: 984,
};

/// Resource vector for one submodule / accelerator.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Resources {
    pub dsp: u32,
    pub lut: u32,
    pub ff: u32,
    pub bram: u32,
}

impl Resources {
    pub fn add(self, o: Resources) -> Resources {
        Resources {
            dsp: self.dsp + o.dsp,
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
        }
    }

    pub fn utilisation(&self, d: &Device) -> (f64, f64, f64, f64) {
        (
            self.dsp as f64 / d.dsps as f64,
            self.lut as f64 / d.luts as f64,
            self.ff as f64 / d.ffs as f64,
            self.bram as f64 / d.bram36 as f64,
        )
    }

    pub fn fits(&self, d: &Device) -> bool {
        self.dsp <= d.dsps && self.lut <= d.luts && self.ff <= d.ffs && self.bram <= d.bram36
    }
}

// --- Unit costs (calibrated once to Table III, see module docs) ----------
// MMU: 1568 DSP, 198,960 LUT, 14,115 FF, 14 BRAM
//   → ~127 LUT / multiplier (operand mux + routing), 9 FF.
// SCU (49 lanes): 49 DSP, 41,184 LUT, 18,708 FF, 4 BRAM
//   → ~840 LUT / lane (FMU compare tree + EU PWL + LOD + DU), 381 FF.
// GCU (49 lanes, 2 EU): 98 DSP, 53,482 LUT, 5,745 FF, 4 BRAM
//   → ~1091 LUT / lane (cubic poly + 2×EU + DU), 117 FF.

const MMU_LUT_PER_MULT: u32 = 127;
const MMU_FF_PER_MULT: u32 = 9;
// pub(crate): the alternative nonlinear designs ([`super::nonlinear`])
// express their LUT/FF deltas relative to these baseline unit costs.
pub(crate) const SCU_LUT_PER_LANE: u32 = 840;
pub(crate) const SCU_FF_PER_LANE: u32 = 381;
pub(crate) const GCU_LUT_PER_LANE: u32 = 1091;
pub(crate) const GCU_FF_PER_LANE: u32 = 117;
pub(crate) const GCU_DSP_PER_LANE: u32 = 2; // x² and x·x² multipliers

/// Infrastructure (MRU/MWU/DSU/control/AXI): fixed overhead + per-variant
/// datapath width scaling, calibrated to Table IV totals.
const INFRA_DSP: u32 = 12;
const INFRA_LUT: u32 = 140_000;
const INFRA_FF: u32 = 232_000;
const INFRA_FF_WIDE_EXTRA: u32 = 107_000; // Swin-B's wider streams (Table IV)
const INFRA_LUT_WIDE_EXTRA: u32 = 14_000;
const INFRA_DSP_WIDE_EXTRA: u32 = 6; // address generators for C=128 strides

pub fn mmu_resources(cfg: &AccelConfig) -> Resources {
    let mults = (cfg.mmu_pes * cfg.mmu_mults_per_pe) as u32;
    Resources {
        dsp: mults,
        lut: mults * MMU_LUT_PER_MULT,
        ff: mults * MMU_FF_PER_MULT,
        bram: 14,
    }
}

/// SCU footprint under the configured nonlinear design (baseline at
/// `AccelConfig::paper()` reproduces Table III exactly).
pub fn scu_resources(cfg: &AccelConfig) -> Resources {
    cfg.nl_design.design().scu_resources(cfg)
}

/// GCU footprint under the configured nonlinear design.
pub fn gcu_resources(cfg: &AccelConfig) -> Resources {
    cfg.nl_design.design().gcu_resources(cfg)
}

/// Whether a variant needs the widened infrastructure (C = 128 datapath —
/// Swin-B in the paper's Table IV).
fn is_wide(v: &SwinVariant) -> bool {
    v.embed_dim > 96
}

/// Infrastructure (MRU/MWU/DSU/control/AXI) for a variant — priced
/// separately so the power model can apply its own activity factor.
pub fn infra_resources(v: &SwinVariant) -> Resources {
    let wide = is_wide(v);
    Resources {
        dsp: INFRA_DSP + if wide { INFRA_DSP_WIDE_EXTRA } else { 0 },
        lut: INFRA_LUT + if wide { INFRA_LUT_WIDE_EXTRA } else { 0 },
        ff: INFRA_FF + if wide { INFRA_FF_WIDE_EXTRA } else { 0 },
        bram: 0,
    }
}

/// On-chip buffer BRAM for a variant (MRU-owned; the MRU busy fraction
/// drives its activity in the power model).
pub fn buffer_resources(v: &SwinVariant) -> Resources {
    Resources {
        dsp: 0,
        lut: 0,
        ff: 0,
        bram: BufferPlan::for_variant(v).total_bram36() as u32 + 8, // + ext-if FIFOs
    }
}

/// Full-accelerator resources for a variant (Table IV).
pub fn accelerator_resources(v: &SwinVariant, cfg: &AccelConfig) -> Resources {
    mmu_resources(cfg)
        .add(scu_resources(cfg))
        .add(gcu_resources(cfg))
        .add(infra_resources(v))
        .add(buffer_resources(v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, SMALL, TINY};

    fn cfg() -> AccelConfig {
        AccelConfig::paper()
    }

    #[test]
    fn table3_mmu_exact_dsp() {
        let r = mmu_resources(&cfg());
        assert_eq!(r.dsp, 1568); // paper: 1568 (79.7 %)
        assert!((r.lut as f64 - 198_960.0).abs() / 198_960.0 < 0.02);
        assert!((r.ff as f64 - 14_115.0).abs() / 14_115.0 < 0.05);
        assert_eq!(r.bram, 14);
    }

    #[test]
    fn table3_scu() {
        let r = scu_resources(&cfg());
        assert_eq!(r.dsp, 49);
        assert!((r.lut as f64 - 41_184.0).abs() / 41_184.0 < 0.02);
        assert!((r.ff as f64 - 18_708.0).abs() / 18_708.0 < 0.02);
    }

    #[test]
    fn table3_gcu() {
        let r = gcu_resources(&cfg());
        assert_eq!(r.dsp, 98);
        assert!((r.lut as f64 - 53_482.0).abs() / 53_482.0 < 0.02);
        assert!((r.ff as f64 - 5_745.0).abs() / 5_745.0 < 0.03);
    }

    #[test]
    fn table4_totals_in_band() {
        // paper: T/S 1727 DSP, 434k LUT, 271k FF, 244 BRAM; B 1733/451k/378k/338
        let t = accelerator_resources(&TINY, &cfg());
        assert!((1700..=1760).contains(&t.dsp), "dsp={}", t.dsp);
        assert!((t.lut as f64 - 434_000.0).abs() / 434_000.0 < 0.08, "lut={}", t.lut);
        assert!((t.ff as f64 - 271_000.0).abs() / 271_000.0 < 0.15, "ff={}", t.ff);
        let b = accelerator_resources(&BASE, &cfg());
        assert!(b.dsp > t.dsp && b.lut > t.lut && b.ff > t.ff && b.bram > t.bram);
        assert!((b.ff as f64 - 378_000.0).abs() / 378_000.0 < 0.15, "b.ff={}", b.ff);
    }

    #[test]
    fn tiny_equals_small() {
        assert_eq!(
            accelerator_resources(&TINY, &cfg()),
            accelerator_resources(&SMALL, &cfg())
        );
    }

    #[test]
    fn everything_fits_the_device() {
        for v in [&TINY, &SMALL, &BASE] {
            let r = accelerator_resources(v, &cfg());
            assert!(r.fits(&XCZU19EG), "{}: {:?}", v.name, r);
        }
    }

    #[test]
    fn alternative_designs_shift_the_totals() {
        use crate::accel::nonlinear::NlDesign;
        let base = accelerator_resources(&TINY, &cfg());
        let quark = accelerator_resources(&TINY, &cfg().nonlinear(NlDesign::Quark));
        let peano = accelerator_resources(&TINY, &cfg().nonlinear(NlDesign::Peano));
        assert_eq!(base.dsp, 1727);
        assert_eq!(quark.dsp, 1678);
        assert_eq!(peano.dsp, 1666);
        assert!(quark.lut < base.lut && peano.lut < base.lut);
        // BRAM is buffer-dominated and design-independent
        assert_eq!(base.bram, quark.bram);
        assert_eq!(base.bram, peano.bram);
    }

    #[test]
    fn dsp_utilisation_matches_paper_fraction() {
        let t = accelerator_resources(&TINY, &cfg());
        let (dsp_u, ..) = t.utilisation(&XCZU19EG);
        // paper: 87.8 %
        assert!((dsp_u - 0.878).abs() < 0.02, "dsp_u={dsp_u}");
    }
}
