//! SCU — Softmax Compute Unit (paper §IV.C, Figs. 6–9).
//!
//! Numerics and cycle cost are design-backed
//! ([`AccelConfig::nl_design`] → [`super::nonlinear::NonlinearDesign`]):
//! the paper's circuits are the baseline design, preserved bit-for-bit;
//! QUARK-style sharing and PEANO-style normalisation swap in through the
//! same struct. The FMU grouping model ([`fmu_cycles`]) is shared by
//! every design — the max front end is common hardware:
//!
//! * FMU (Fig. 7): elements split into power-of-two groups — for n = 49:
//!   {32, 16, 1}. Group compare trees run in parallel; the deepest group
//!   dominates (⌈log₂ 32⌉ = 5) and the final cross-group merge with the
//!   straggler x₄₈ is folded into the last cycle, giving the paper's
//!   6 cycles for n = 49 (vs 48 cycles for a linear scan).
//! * EU / AdderTree / DU stages pipeline at one row per `II = 1` once
//!   filled ([`AccelConfig::scu_depth`] covers the fill; QUARK shares
//!   the pipe at II = 2, PEANO shortens the fill).

use super::AccelConfig;

/// FMU latency for an n-element max (paper Fig. 7 grouping).
///
/// n splits into power-of-two groups (greedy, largest first); each
/// group's compare tree produces its maximum at cycle log₂(size).
/// Cross-group results merge as soon as available — the paper's
/// example: Group 2 (16 elems) finishes at cycle 4, absorbs x₄₈ at
/// cycle 5, and the final merge with Group 1 (ready at 5) lands at
/// cycle 6 for n = 49.
pub fn fmu_cycles(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    // ready times of each group's partial max
    let mut ready: Vec<u64> = Vec::new();
    let mut rem = n;
    while rem > 0 {
        let g = 1usize << (usize::BITS - 1 - rem.leading_zeros());
        ready.push(g.trailing_zeros() as u64);
        rem -= g;
    }
    // repeatedly merge the two earliest-ready partials (each merge is
    // one comparator): new ready = max(a, b) + 1
    while ready.len() > 1 {
        ready.sort_unstable();
        let a = ready.remove(0);
        let b = ready.remove(0);
        ready.push(a.max(b) + 1);
    }
    ready[0]
}

#[derive(Debug, Clone)]
pub struct Scu {
    cfg: AccelConfig,
}

impl Scu {
    pub fn new(cfg: AccelConfig) -> Self {
        Scu { cfg }
    }

    /// Functional: softmax over a (rows × width) score matrix,
    /// Q7.8 → Q0.15, through the configured design's kernel.
    pub fn softmax(&self, scores: &[i32], width: usize) -> Vec<i32> {
        self.cfg.nl_design.design().softmax(scores, width)
    }

    /// FMU latency for an n-element max (paper Fig. 7 grouping).
    pub fn fmu_cycles(&self, n: usize) -> u64 {
        fmu_cycles(n)
    }

    /// Linear-scan FMU baseline (the "unacceptable" 48-cycle variant the
    /// paper argues against; kept for the ablation bench).
    pub fn fmu_cycles_linear(&self, n: usize) -> u64 {
        n.saturating_sub(1) as u64
    }

    /// Cycles to softmax `rows` rows of `width` lanes under the
    /// configured design (baseline: one row per cycle once filled;
    /// fill = FMU + EU + adder tree + DU + EU depth).
    pub fn softmax_cycles(&self, rows: usize, width: usize) -> u64 {
        self.cfg
            .nl_design
            .design()
            .softmax_cycles(&self.cfg, rows, width)
    }

    /// Cycles exposed on the critical path when softmax overlaps the
    /// MMU's next window (`overlap_nonlinear`).
    pub fn softmax_exposed(&self, rows: usize, width: usize) -> u64 {
        self.cfg
            .nl_design
            .design()
            .softmax_exposed(&self.cfg, rows, width)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scu() -> Scu {
        Scu::new(AccelConfig::paper())
    }

    #[test]
    fn fmu_matches_paper_for_49() {
        // paper §IV.C.2: "finding the maximum value of elements in a
        // vector of length 49 would require 6 cycles"
        assert_eq!(scu().fmu_cycles(49), 6);
    }

    #[test]
    fn fmu_power_of_two_sizes() {
        let s = scu();
        assert_eq!(s.fmu_cycles(2), 1);
        assert_eq!(s.fmu_cycles(32), 5);
        assert_eq!(s.fmu_cycles(64), 6);
        assert_eq!(s.fmu_cycles(1), 0);
    }

    #[test]
    fn fmu_much_faster_than_linear() {
        let s = scu();
        for n in [16usize, 49, 64, 128] {
            assert!(s.fmu_cycles(n) * 4 < s.fmu_cycles_linear(n).max(1) * 2 + 8);
            assert!(s.fmu_cycles(n) <= (n as f64).log2().ceil() as u64 + 1);
        }
    }

    #[test]
    fn softmax_cycles_scale_with_rows() {
        // marginal cost is exactly one cycle per row (II = 1 pipeline);
        // the fill is paid once
        let s = scu();
        let fill = s.fmu_cycles(49) + AccelConfig::paper().scu_depth;
        let one = s.softmax_cycles(49, 49);
        let many = s.softmax_cycles(490, 49);
        assert_eq!(one - fill, 49);
        assert_eq!(many - fill, 490);
    }

    #[test]
    fn functional_delegates_to_golden() {
        let s = scu();
        let scores: Vec<i32> = (0..98).map(|i| (i % 49) * 10 - 200).collect();
        let got = s.softmax(&scores, 49);
        let want = crate::approx::softmax::softmax_rows(&scores, 49);
        assert_eq!(got, want);
    }

    #[test]
    fn design_dispatch_switches_numerics_and_cycles() {
        use crate::accel::nonlinear::NlDesign;
        let p = Scu::new(AccelConfig::paper().nonlinear(NlDesign::Peano));
        let scores: Vec<i32> = (0..49).map(|i| (i % 49) * 10 - 200).collect();
        assert_eq!(
            p.softmax(&scores, 49),
            crate::approx::peano::softmax_rows_peano(&scores, 49)
        );
        assert!(p.softmax_cycles(49, 49) < scu().softmax_cycles(49, 49));
        let q = Scu::new(AccelConfig::paper().nonlinear(NlDesign::Quark));
        assert!(q.softmax_cycles(49, 49) > scu().softmax_cycles(49, 49));
    }
}
