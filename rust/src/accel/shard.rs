//! Model sharding across cards — the pipeline-parallel layer of the
//! timing stack.
//!
//! The paper's single-card design tops out at Swin-B/224: the Swin-L and
//! 384-input variants blow the XCZU19EG's 984-BRAM budget (the ILB's
//! scores/probs buffers grow as M⁴·heads, see
//! [`super::buffers::BufferPlan`]). The classic answer — Lu et al.'s
//! stage-partitioned hardware pipeline — maps directly onto the existing
//! IR:
//!
//! * [`ShardPlan`] partitions a variant's stages across cards greedily:
//!   each shard takes as many consecutive stages as its card's BRAM
//!   budget admits ([`BufferPlan::for_stage_range`] prices the range).
//! * [`ShardedSchedule`] lowers one [`PipelineSchedule`] per shard
//!   ([`PipelineSchedule::for_variant_stages`]) and connects stage K on
//!   card A to stage K+1 on card B with an inter-card
//!   [`Resource::Link`]: the activation tensor at the cut (2 bytes/elem
//!   × tokens × channels, exactly the map a `PatchMerge` would consume)
//!   priced via [`MemoryModel::transfer_cycles`] — ViTA's prefetch
//!   structure extended across the link, since weights stay card-local.
//! * [`ShardedSequencePlacer`] appends launches across every shard on
//!   ONE absolute timeline: shard k+1's first compute is gated on the
//!   link transfer landing ([`SequencePlacer::append_gated`]). Each link
//!   serialises its own transfers, but a batch-b transfer is *chunked
//!   per image* (activation double-buffering at the cut): downstream
//!   replica i starts once chunk i has landed instead of waiting for the
//!   whole serialised block ([`ShardedSchedule::link_gate`] — batch 1 is
//!   bit-identical to the unchunked gate, batch>1 only tightens).
//!   Warm/cold entry rules apply per shard — every card runs its own
//!   warm queue.
//!
//! A single-shard plan lowers **bit-for-bit** to today's unsharded
//! schedule: the stage range covers everything, no link exists, and the
//! `input_ready = 0` gate is the identity on the placement recurrence.
//!
//! Steady state: the converged per-launch increment of the composite
//! max-plus recurrence is the slowest component's rate — the maximum
//! over every shard's own steady increment and every link's transfer
//! time. Throughput of the sharded pipeline is therefore the *slowest
//! shard's* warm throughput (the acceptance bound); end-to-end latency
//! is the sum of shard spans plus link transfers.

use std::ops::Range;
use std::sync::Arc;

use crate::model::config::SwinVariant;
use crate::model::graph::WorkloadGraph;
use crate::util::json::Json;

use super::buffers::{BufferPlan, XCZU19EG_BRAM36};
use super::memory::MemoryModel;
use super::pipeline::{LaunchSpan, PipelineSchedule, Resource, Segment, SequencePlacer};
use super::AccelConfig;

/// One shard of a [`ShardPlan`]: a consecutive stage range hosted by one
/// card, with its capacity verdict and weight-stream footprint.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Hosted stages, in *global* stage indices.
    pub stages: Range<usize>,
    /// BRAM36 blocks of this shard's own buffer plan
    /// ([`BufferPlan::for_stage_range`]).
    pub bram36: usize,
    /// Whether the shard fits its card's BRAM budget. The greedy
    /// partition never splits below one stage, so a single stage wider
    /// than the budget is carried with `fits = false` (Swin-L/384's
    /// stage 3 exceeds one XCZU19EG even alone — honest capacity
    /// reporting; the cycle model is unaffected).
    pub fits: bool,
    /// Per-launch DDR-streamed weight bytes of the hosted stages (for
    /// balance reporting — the link cuts are chosen by BRAM, weights
    /// stream from each card's own DDR).
    pub weight_bytes: u64,
}

/// A stage→card partition of one variant: the sharding decision, chosen
/// greedily by per-stage buffer cost against a per-card BRAM budget.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub variant: SwinVariant,
    /// Per-card BRAM36 budget the partition was chosen against.
    pub budget: usize,
    pub shards: Vec<Shard>,
    /// Activation bytes crossing each cut (len = shards − 1): the full
    /// feature map entering shard k+1's first stage, per image.
    pub cut_bytes: Vec<u64>,
}

impl ShardPlan {
    /// Partition `v` across XCZU19EG cards (984 BRAM36 each).
    pub fn for_variant(v: &SwinVariant) -> Self {
        Self::for_budget(v, XCZU19EG_BRAM36)
    }

    /// Greedy partition: each shard extends its stage range while the
    /// range's [`BufferPlan::for_stage_range`] stays within `budget`;
    /// every shard hosts at least one stage. A variant that fits whole
    /// yields the single-shard plan (which lowers bit-identically to the
    /// unsharded schedule).
    pub fn for_budget(v: &SwinVariant, budget: usize) -> Self {
        let ns = v.num_stages();
        let graph = WorkloadGraph::build(v);
        let mut stage_weights = vec![0u64; ns];
        for op in &graph.ops {
            stage_weights[op.stage] += op.weight_bytes as u64;
        }
        let mut shards = Vec::new();
        let mut lo = 0usize;
        while lo < ns {
            let mut hi = lo + 1;
            while hi < ns && BufferPlan::for_stage_range(v, lo, hi + 1).total_bram36() <= budget {
                hi += 1;
            }
            let plan = BufferPlan::for_stage_range(v, lo, hi);
            shards.push(Shard {
                stages: lo..hi,
                bram36: plan.total_bram36(),
                fits: plan.fits_device(budget),
                weight_bytes: stage_weights[lo..hi].iter().sum(),
            });
            lo = hi;
        }
        let cut_bytes = shards
            .windows(2)
            .map(|w| {
                let s = w[1].stages.start;
                let tokens = (v.stage_resolution(s) * v.stage_resolution(s)) as u64;
                2 * tokens * v.stage_dim(s) as u64
            })
            .collect();
        ShardPlan {
            variant: v.clone(),
            budget,
            shards,
            cut_bytes,
        }
    }

    /// Number of cards the plan spans.
    pub fn cards(&self) -> usize {
        self.shards.len()
    }

    /// A one-card plan (lowers bit-identically to the unsharded IR).
    pub fn is_single(&self) -> bool {
        self.shards.len() == 1
    }

    /// Do *all* shards fit their card's budget?
    pub fn fits_budget(&self) -> bool {
        self.shards.iter().all(|s| s.fits)
    }

    /// Compact JSON view (plan reporting / the metrics endpoint).
    pub fn summary_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("variant".into(), Json::Str(self.variant.name.into()));
        obj.insert("budget_bram36".into(), Json::Num(self.budget as f64));
        obj.insert("cards".into(), Json::Num(self.cards() as f64));
        obj.insert("fits_budget".into(), Json::Bool(self.fits_budget()));
        obj.insert(
            "shards".into(),
            Json::Arr(
                self.shards
                    .iter()
                    .map(|s| {
                        let mut o = std::collections::BTreeMap::new();
                        o.insert(
                            "stages".into(),
                            Json::Arr(vec![
                                Json::Num(s.stages.start as f64),
                                Json::Num(s.stages.end as f64),
                            ]),
                        );
                        o.insert("bram36".into(), Json::Num(s.bram36 as f64));
                        o.insert("fits".into(), Json::Bool(s.fits));
                        o.insert("weight_bytes".into(), Json::Num(s.weight_bytes as f64));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        obj.insert(
            "cut_bytes".into(),
            Json::Arr(self.cut_bytes.iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        Json::Obj(obj)
    }
}

/// One launch placed across every shard of a sharded pipeline, on one
/// absolute timeline.
#[derive(Debug, Clone)]
pub struct ShardedLaunchSpan {
    pub batch: usize,
    /// First event (shard 0's first stream start).
    pub start: u64,
    /// Completion (the last shard's launch end).
    pub end: u64,
    /// Per-shard launch spans, absolute on the shared timeline.
    pub shards: Vec<LaunchSpan>,
    /// Link transfers `(start, end)` at each cut, upstream → downstream.
    pub links: Vec<(u64, u64)>,
}

/// A placed sequence of sharded launches (the sharded counterpart of
/// [`super::pipeline::SequenceSchedule`]).
#[derive(Debug, Clone)]
pub struct ShardedSequence {
    pub variant: &'static str,
    pub launches: Vec<ShardedLaunchSpan>,
    pub total_cycles: u64,
}

/// The lowered sharded schedule: one per-card [`PipelineSchedule`] per
/// shard plus the link cost model.
#[derive(Debug, Clone)]
pub struct ShardedSchedule {
    pub plan: ShardPlan,
    pub cfg: AccelConfig,
    /// Per-shard schedules (unit stage indices stay global).
    pub shards: Vec<Arc<PipelineSchedule>>,
    mem: MemoryModel,
}

impl ShardedSchedule {
    /// Lower every shard of `plan` under `cfg`.
    pub fn for_plan(plan: ShardPlan, cfg: AccelConfig) -> Self {
        let shards = plan
            .shards
            .iter()
            .map(|s| {
                Arc::new(PipelineSchedule::for_variant_stages(
                    &plan.variant,
                    cfg.clone(),
                    s.stages.start,
                    s.stages.end,
                ))
            })
            .collect();
        let mem = MemoryModel::new(cfg.clone());
        ShardedSchedule {
            plan,
            cfg,
            shards,
            mem,
        }
    }

    /// Partition `variant` for XCZU19EG cards and lower it.
    pub fn for_variant(variant: &SwinVariant, cfg: AccelConfig) -> Self {
        Self::for_plan(ShardPlan::for_variant(variant), cfg)
    }

    pub fn cards(&self) -> usize {
        self.shards.len()
    }

    /// Cycles link `k` (between shard k and k+1) needs to move one
    /// batch-`batch` activation tensor at the cut — the wire's total
    /// occupancy (the per-image chunks stream back to back).
    pub fn link_cycles(&self, k: usize, batch: usize) -> u64 {
        self.mem
            .transfer_cycles(self.plan.cut_bytes[k] * batch.max(1) as u64)
    }

    /// Input-ready gate of the downstream shard for a link-`k` transfer
    /// starting at `start`: the transfer is chunked per image
    /// (activation double-buffering at the cut), so downstream replica
    /// *i* — which computes at `compute_start + i·c₀`, `c₀` the first
    /// unit's per-replica compute — only needs chunk *i* landed, not the
    /// whole batch. The gate is the tightest compute_start satisfying
    /// every replica: `max_i (start + T(i+1) − i·c₀)` with `T(i)` the
    /// cumulative transfer cycles of `i` chunks. Batch 1 degenerates to
    /// `start + link_cycles(k, 1)` — bit-identical to the serialised
    /// pre-chunking gate — and the gate never exceeds `start + T(b)`, so
    /// batch>1 cold latency only tightens.
    fn link_gate(&self, k: usize, batch: usize, start: u64) -> u64 {
        let b = batch.max(1) as u64;
        let c0 = self.shards[k + 1].units.first().map_or(0, |u| u.compute);
        let mut gate = 0u64;
        for i in 0..b {
            let landed = self.mem.transfer_cycles(self.plan.cut_bytes[k] * (i + 1));
            gate = gate.max((start + landed).saturating_sub(i * c0));
        }
        gate
    }

    /// End-to-end cold latency of one batch-`batch` launch: the sum of
    /// shard spans plus link transfers, as placed (shard k+1's compute
    /// gated on link k landing).
    pub fn launch_cycles(&self, batch: usize) -> u64 {
        ShardedSequencePlacer::new(self).append(batch).end
    }

    /// Cold launch latency in milliseconds.
    pub fn launch_ms(&self, batch: usize) -> f64 {
        self.cfg.cycles_to_ms(self.launch_cycles(batch))
    }

    /// Steady-state per-launch increment of an infinite back-to-back
    /// queue of batch-`batch` launches through the sharded pipeline —
    /// the converged fixed point of [`ShardedSequencePlacer::append`]
    /// (composite placer state repeating exactly). Equals the slowest
    /// component's rate: `max(max_k shard_k steady, max_k link_k)`.
    ///
    /// Note: unlike the single-card schedule, the steady increment sits
    /// below the cold latency even with `overlap_interlaunch` off —
    /// cards overlap *different* launches (pipeline parallelism) even
    /// when each card runs its own launches behind barriers.
    pub fn steady_launch_cycles(&self, batch: usize) -> u64 {
        let mut sp = ShardedSequencePlacer::new(self);
        let mut prev_end = sp.append(batch).end;
        let mut inc = prev_end;
        let mut prev_sig = sp.state_signature();
        for _ in 0..64 {
            let end = sp.append(batch).end;
            let next = end - prev_end;
            let sig = sp.state_signature();
            if next == inc && sig == prev_sig {
                return inc;
            }
            inc = next;
            prev_end = end;
            prev_sig = sig;
        }
        inc
    }

    /// Place a back-to-back sharded launch sequence on one absolute
    /// timeline ([`ShardedSequencePlacer`] is the streaming form).
    pub fn sequence(&self, batches: &[usize]) -> ShardedSequence {
        let mut sp = ShardedSequencePlacer::new(self);
        let launches: Vec<ShardedLaunchSpan> = batches.iter().map(|&b| sp.append(b)).collect();
        ShardedSequence {
            variant: self.plan.variant.name,
            total_cycles: launches.last().map_or(0, |l| l.end),
            launches,
        }
    }

    /// Total cycles of a sharded launch sequence.
    pub fn sequence_cycles(&self, batches: &[usize]) -> u64 {
        self.sequence(batches).total_cycles
    }

    /// Card-resource segments of shard `k` over a placed sequence,
    /// labels prefixed `shard<k>/L<j>:`. Resources are per-card physical
    /// units — shard 0's MMU and shard 1's MMU are different engines and
    /// may legitimately overlap in time.
    pub fn shard_segments(&self, seq: &ShardedSequence, k: usize) -> Vec<Segment> {
        let mut segs = Vec::new();
        for (j, l) in seq.launches.iter().enumerate() {
            self.shards[k].emit_segments(
                &l.shards[k].spans,
                l.batch,
                &format!("shard{k}/L{j}:"),
                &mut segs,
            );
        }
        segs
    }

    /// Transfer segments of link `k` (between shard k and k+1) over a
    /// placed sequence, labels `link<k>/L<j>:xfer`, on
    /// [`Resource::Link`]. Each cut is its own physical link; only
    /// same-`k` segments share a wire.
    pub fn link_segments(&self, seq: &ShardedSequence, k: usize) -> Vec<Segment> {
        seq.launches
            .iter()
            .enumerate()
            .map(|(j, l)| {
                let (start, end) = l.links[k];
                Segment {
                    unit: Resource::Link,
                    label: format!("link{k}/L{j}:xfer"),
                    start,
                    end,
                }
            })
            .collect()
    }

    /// Every segment of a placed sequence: all shards' card resources
    /// plus all link transfers (flat; the label prefixes carry the
    /// card/link attribution).
    pub fn sequence_segments(&self, seq: &ShardedSequence) -> Vec<Segment> {
        let mut segs = Vec::new();
        for k in 0..self.cards() {
            segs.extend(self.shard_segments(seq, k));
            if k + 1 < self.cards() {
                segs.extend(self.link_segments(seq, k));
            }
        }
        segs
    }

    /// Compact JSON summary (plan + per-bucket cold/warm costs + link
    /// transfer cycles) for the metrics endpoint and reports.
    pub fn summary_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("variant".into(), Json::Str(self.plan.variant.name.into()));
        obj.insert("cards".into(), Json::Num(self.cards() as f64));
        obj.insert("plan".into(), self.plan.summary_json());
        let mut launches = std::collections::BTreeMap::new();
        let mut steady = std::collections::BTreeMap::new();
        for b in [1usize, 2, 4, 8] {
            launches.insert(b.to_string(), Json::Num(self.launch_cycles(b) as f64));
            steady.insert(b.to_string(), Json::Num(self.steady_launch_cycles(b) as f64));
        }
        obj.insert("launch_cycles".into(), Json::Obj(launches));
        obj.insert("steady_launch_cycles".into(), Json::Obj(steady));
        obj.insert(
            "link_cycles_b1".into(),
            Json::Arr(
                (0..self.plan.cut_bytes.len())
                    .map(|k| Json::Num(self.link_cycles(k, 1) as f64))
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }
}

/// Streaming sharded-sequence placement: one persistent
/// [`SequencePlacer`] per shard plus per-link serialisation state.
/// Appending a launch walks the shards upstream→downstream: shard k's
/// launch is appended warm/cold per its own card's history, gated on the
/// upstream link transfer landing; link k's transfer starts when shard
/// k's launch completes *and* the link is free.
pub struct ShardedSequencePlacer<'a> {
    schedule: &'a ShardedSchedule,
    placers: Vec<SequencePlacer<'a>>,
    /// Link k frees at `link_free[k]` (its previous transfer's end).
    link_free: Vec<u64>,
    launches: usize,
    end: u64,
}

impl<'a> ShardedSequencePlacer<'a> {
    pub fn new(schedule: &'a ShardedSchedule) -> Self {
        ShardedSequencePlacer {
            placers: schedule
                .shards
                .iter()
                .map(|s| SequencePlacer::new(s.as_ref()))
                .collect(),
            link_free: vec![0; schedule.cards().saturating_sub(1)],
            schedule,
            launches: 0,
            end: 0,
        }
    }

    /// Place the next launch across every shard and return its absolute
    /// sharded span.
    pub fn append(&mut self, batch: usize) -> ShardedLaunchSpan {
        let n = self.placers.len();
        let mut input_ready = 0u64;
        let mut shards = Vec::with_capacity(n);
        let mut links = Vec::with_capacity(n.saturating_sub(1));
        for k in 0..n {
            let l = self.placers[k].append_gated(batch, input_ready);
            if k + 1 < n {
                let dur = self.schedule.link_cycles(k, batch);
                let start = l.end.max(self.link_free[k]);
                self.link_free[k] = start + dur;
                links.push((start, start + dur));
                // per-image chunking: the downstream shard starts once
                // its first chunk(s) land instead of waiting for the
                // whole serialised block (see [`ShardedSchedule::link_gate`])
                input_ready = self.schedule.link_gate(k, batch, start);
            }
            shards.push(l);
        }
        self.launches += 1;
        let start = shards.first().map_or(self.end, |l| l.start);
        let end = shards.last().map_or(self.end, |l| l.end);
        self.end = self.end.max(end);
        ShardedLaunchSpan {
            batch: batch.max(1),
            start,
            end,
            shards,
            links,
        }
    }

    /// Launches appended so far.
    pub fn launches(&self) -> usize {
        self.launches
    }

    /// Completion of the last appended launch (0 before any append).
    pub fn total_cycles(&self) -> u64 {
        self.end
    }

    /// Composite normalized state: every shard placer's own signature
    /// plus its end's backward offset from the global end, plus every
    /// link's free-time offset. Equal composite signatures across two
    /// appends of the same batch prove the sharded pipeline reached its
    /// steady state (each component's state repeats *and* their relative
    /// alignment repeats).
    #[allow(clippy::type_complexity)]
    fn state_signature(&self) -> (Vec<(u64, (usize, u64, u64, Vec<u64>))>, Vec<u64>) {
        let origin = self.end;
        (
            self.placers
                .iter()
                .map(|p| (origin - p.total_cycles(), p.state_signature()))
                .collect(),
            self.link_free
                .iter()
                .map(|&t| origin.saturating_sub(t))
                .collect(),
        )
    }
}

/// Shared launch-cost table of a sharded pipeline — the sharded
/// counterpart of [`super::pipeline::CostTable`], with the same
/// memoized cold/warm per-bucket contract the serving hot path expects:
/// cold = end-to-end pipeline latency, warm = steady per-launch
/// increment (the slowest shard's rate).
#[derive(Debug, Clone)]
pub struct ShardCostTable {
    schedule: Arc<ShardedSchedule>,
    /// `(batch, cold cycles, warm cycles)`, sorted by batch.
    entries: Vec<(usize, u64, u64)>,
}

impl ShardCostTable {
    /// Build the table for `buckets` over an already-lowered schedule.
    pub fn from_schedule(schedule: ShardedSchedule, buckets: &[usize]) -> Self {
        Self::from_arc(Arc::new(schedule), buckets)
    }

    /// Build the table over a shared schedule (no re-lowering).
    pub fn from_arc(schedule: Arc<ShardedSchedule>, buckets: &[usize]) -> Self {
        let mut sizes: Vec<usize> = buckets.iter().map(|&b| b.max(1)).collect();
        sizes.sort_unstable();
        sizes.dedup();
        let entries = sizes
            .into_iter()
            .map(|b| {
                (
                    b,
                    schedule.launch_cycles(b),
                    schedule.steady_launch_cycles(b),
                )
            })
            .collect();
        ShardCostTable { schedule, entries }
    }

    /// Partition + lower `variant` under `cfg` and memoize `buckets`.
    pub fn for_variant(variant: &SwinVariant, cfg: AccelConfig, buckets: &[usize]) -> Self {
        Self::from_schedule(ShardedSchedule::for_variant(variant, cfg), buckets)
    }

    /// The underlying sharded schedule.
    pub fn schedule(&self) -> &ShardedSchedule {
        &self.schedule
    }

    /// Share the schedule itself.
    pub fn share_schedule(&self) -> Arc<ShardedSchedule> {
        Arc::clone(&self.schedule)
    }

    /// A copy extended to also memoize `sizes` (shares the schedule).
    pub fn with_buckets(&self, sizes: &[usize]) -> Self {
        let mut t = self.clone();
        for &b in sizes {
            let b = b.max(1);
            if let Err(i) = t.entries.binary_search_by_key(&b, |e| e.0) {
                t.entries.insert(
                    i,
                    (
                        b,
                        t.schedule.launch_cycles(b),
                        t.schedule.steady_launch_cycles(b),
                    ),
                );
            }
        }
        t
    }

    /// Memoized buckets, ascending.
    pub fn buckets(&self) -> impl Iterator<Item = usize> + '_ {
        self.entries.iter().map(|e| e.0)
    }

    /// Cold end-to-end pipeline latency of one batch-`batch` launch.
    pub fn cold_cycles(&self, batch: usize) -> u64 {
        let b = batch.max(1);
        match self.entries.binary_search_by_key(&b, |e| e.0) {
            Ok(i) => self.entries[i].1,
            Err(_) => self.schedule.launch_cycles(b),
        }
    }

    /// Warm (steady-state) per-launch increment.
    pub fn warm_cycles(&self, batch: usize) -> u64 {
        let b = batch.max(1);
        match self.entries.binary_search_by_key(&b, |e| e.0) {
            Ok(i) => self.entries[i].2,
            Err(_) => self.schedule.steady_launch_cycles(b),
        }
    }

    /// Cold latency in milliseconds.
    pub fn cold_ms(&self, batch: usize) -> f64 {
        self.schedule.cfg.cycles_to_ms(self.cold_cycles(batch))
    }

    /// Warm steady-state service time in milliseconds.
    pub fn warm_ms(&self, batch: usize) -> f64 {
        self.schedule.cfg.cycles_to_ms(self.warm_cycles(batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE_384, LARGE_384, MICRO, TINY};

    #[test]
    fn fitting_variants_get_single_shard_plans() {
        for v in [&MICRO, &TINY] {
            let p = ShardPlan::for_variant(v);
            assert!(p.is_single(), "{}", v.name);
            assert!(p.fits_budget(), "{}", v.name);
            assert!(p.cut_bytes.is_empty());
            assert_eq!(p.shards[0].stages, 0..v.num_stages());
        }
    }

    #[test]
    fn base_384_splits_into_two_fitting_shards() {
        let p = ShardPlan::for_variant(&BASE_384);
        assert_eq!(p.cards(), 2, "{:?}", p.shards);
        assert!(p.fits_budget());
        assert_eq!(p.shards[0].stages, 0..3);
        assert_eq!(p.shards[1].stages, 3..4);
        // the cut carries the stage-3 input map: 2 B × 12² tokens × 1024
        assert_eq!(p.cut_bytes, vec![2 * 12 * 12 * 1024]);
    }

    #[test]
    fn large_384_partition_is_honest_about_stage_3() {
        let p = ShardPlan::for_variant(&LARGE_384);
        assert_eq!(p.cards(), 2, "{:?}", p.shards);
        assert!(p.shards[0].fits);
        // stage 3 alone (48 heads × 12⁴ scores) exceeds one XCZU19EG;
        // the plan carries it on its own card and reports the deficit
        assert!(!p.shards[1].fits);
        assert!(!p.fits_budget());
        // a roomier card budget absorbs the whole model on one shard
        let roomy = ShardPlan::for_budget(&LARGE_384, 1536);
        assert!(roomy.is_single());
        assert!(roomy.fits_budget());
    }

    #[test]
    fn single_shard_lowers_bit_identically_to_the_unsharded_schedule() {
        for cfg in [
            AccelConfig::paper(),
            AccelConfig::paper().interlaunch(false),
            AccelConfig::paper().sequential(),
        ] {
            let flat = PipelineSchedule::for_variant(&MICRO, cfg.clone());
            let sharded = ShardedSchedule::for_variant(&MICRO, cfg);
            assert_eq!(sharded.cards(), 1);
            for b in [1usize, 2, 4, 8] {
                assert_eq!(sharded.launch_cycles(b), flat.launch_cycles(b), "b={b}");
                assert_eq!(
                    sharded.steady_launch_cycles(b),
                    flat.steady_launch_cycles(b),
                    "b={b}"
                );
            }
            let batches = [1usize, 8, 2, 4];
            assert_eq!(
                sharded.sequence_cycles(&batches),
                flat.sequence_cycles(&batches)
            );
            // spans agree unit by unit
            let seq = sharded.sequence(&batches);
            let flat_seq = flat.sequence(&batches);
            for (l, fl) in seq.launches.iter().zip(&flat_seq.launches) {
                assert!(l.links.is_empty());
                assert_eq!(l.start, fl.start);
                assert_eq!(l.end, fl.end);
                for (a, b) in l.shards[0].spans.iter().zip(&fl.spans) {
                    assert_eq!(a.stream_start, b.stream_start);
                    assert_eq!(a.compute_end, b.compute_end);
                }
            }
        }
    }

    #[test]
    fn links_never_start_before_their_producer_completes() {
        let s = ShardedSchedule::for_variant(&BASE_384, AccelConfig::paper());
        assert_eq!(s.cards(), 2);
        let seq = s.sequence(&[8, 4, 8, 1]);
        for l in &seq.launches {
            for (k, &(start, end)) in l.links.iter().enumerate() {
                assert!(start >= l.shards[k].end, "link {k} outruns its producer");
                assert_eq!(end - start, s.link_cycles(k, l.batch));
                // the consumer's first replica waits for its own chunk…
                assert!(l.shards[k + 1].spans[0].compute_start >= start + s.link_cycles(k, 1));
                // …and the consumer's first unit cannot drain before the
                // last chunk has landed (replica b consumes image b)
                assert!(l.shards[k + 1].spans[0].compute_end >= end);
                // batch 1: chunked gate degenerates to the full transfer
                if l.batch == 1 {
                    assert!(l.shards[k + 1].spans[0].compute_start >= end);
                }
            }
        }
    }

    /// The pre-chunking placement: downstream compute gated on the FULL
    /// serialised batch-b transfer (one `cut_bytes × b` block). The
    /// chunked placer must never be slower than this, and must match it
    /// bit-for-bit at batch 1.
    fn serialized_gate_launch_end(s: &ShardedSchedule, batches: &[usize]) -> u64 {
        let mut placers: Vec<SequencePlacer> = s
            .shards
            .iter()
            .map(|sh| SequencePlacer::new(sh.as_ref()))
            .collect();
        let mut link_free = vec![0u64; s.cards().saturating_sub(1)];
        let mut end = 0u64;
        for &b in batches {
            let mut input_ready = 0u64;
            for k in 0..placers.len() {
                let l = placers[k].append_gated(b, input_ready);
                if k + 1 < placers.len() {
                    let dur = s.link_cycles(k, b);
                    let start = l.end.max(link_free[k]);
                    link_free[k] = start + dur;
                    input_ready = start + dur;
                }
                end = l.end;
            }
        }
        end
    }

    #[test]
    fn chunked_links_only_tighten_cold_latency() {
        for v in [&BASE_384, &LARGE_384] {
            for budget in [XCZU19EG_BRAM36, 512] {
                let plan = ShardPlan::for_budget(v, budget);
                if plan.is_single() {
                    continue;
                }
                let s = ShardedSchedule::for_plan(plan, AccelConfig::paper());
                for b in [1usize, 2, 4, 8] {
                    let new = s.launch_cycles(b);
                    let old = serialized_gate_launch_end(&s, &[b]);
                    assert!(new <= old, "{} budget={budget} b={b}: {new} > {old}", v.name);
                    if b == 1 {
                        assert_eq!(new, old, "{} budget={budget}", v.name);
                    } else {
                        // the registry cuts are bandwidth-heavy enough
                        // that overlapping chunks with downstream compute
                        // is a real win, not a tie
                        assert!(new < old, "{} budget={budget} b={b}", v.name);
                    }
                }
                // multi-launch sequences stay ordered too
                let batches = [8usize, 4, 8, 1];
                assert!(s.sequence_cycles(&batches) <= serialized_gate_launch_end(&s, &batches));
            }
        }
    }

    #[test]
    fn steady_increment_is_the_slowest_component_rate() {
        for cfg in [AccelConfig::paper(), AccelConfig::paper().interlaunch(false)] {
            let s = ShardedSchedule::for_variant(&BASE_384, cfg);
            for b in [1usize, 8] {
                let slowest = s
                    .shards
                    .iter()
                    .map(|sh| sh.steady_launch_cycles(b))
                    .chain((0..s.cards() - 1).map(|k| s.link_cycles(k, b)))
                    .max()
                    .unwrap();
                assert_eq!(s.steady_launch_cycles(b), slowest, "b={b}");
                // pipeline parallelism: steady strictly below cold latency
                assert!(s.steady_launch_cycles(b) < s.launch_cycles(b), "b={b}");
            }
        }
    }

    #[test]
    fn shard_cost_table_matches_the_schedule() {
        let s = ShardedSchedule::for_variant(&BASE_384, AccelConfig::paper());
        let t = ShardCostTable::for_variant(&BASE_384, AccelConfig::paper(), &[8, 4, 2, 1]);
        for b in [1usize, 2, 4, 8] {
            assert_eq!(t.cold_cycles(b), s.launch_cycles(b), "b={b}");
            assert_eq!(t.warm_cycles(b), s.steady_launch_cycles(b), "b={b}");
            assert!(t.warm_cycles(b) <= t.cold_cycles(b));
        }
        let t2 = t.with_buckets(&[3]);
        assert_eq!(t2.cold_cycles(3), s.launch_cycles(3));
        assert!(Arc::ptr_eq(&t.share_schedule(), &t2.share_schedule()));
    }

    #[test]
    fn sharded_segments_carry_track_prefixes_and_stay_in_window() {
        let s = ShardedSchedule::for_variant(&BASE_384, AccelConfig::paper());
        let seq = s.sequence(&[2, 2]);
        let segs = s.sequence_segments(&seq);
        assert!(segs.iter().any(|e| e.label.starts_with("shard0/L0:")));
        assert!(segs.iter().any(|e| e.label.starts_with("shard1/L1:")));
        assert!(segs
            .iter()
            .any(|e| e.unit == Resource::Link && e.label.starts_with("link0/")));
        for e in &segs {
            assert!(e.end >= e.start);
            assert!(e.end <= seq.total_cycles, "{} overruns", e.label);
        }
        // per-link non-overlap: one wire serialises its transfers
        let links = s.link_segments(&seq, 0);
        for w in links.windows(2) {
            assert!(w[1].start >= w[0].end);
        }
    }

    #[test]
    fn summary_json_reports_the_plan() {
        let s = ShardedSchedule::for_variant(&BASE_384, AccelConfig::paper());
        let j = Json::parse(&s.summary_json().to_string()).unwrap();
        assert_eq!(j.get("variant").unwrap().as_str(), Some("swin-b-384"));
        assert_eq!(j.get("cards").unwrap().as_usize(), Some(2));
        let plan = j.get("plan").unwrap();
        assert_eq!(plan.get("shards").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(plan.get("fits_budget").unwrap().as_bool(), Some(true));
        assert!(j.get("steady_launch_cycles").unwrap().get("8").is_some());
    }
}
