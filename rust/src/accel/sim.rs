//! Whole-model cycle simulation → the paper's Table V numbers
//! (FPS, GOPS, latency) and per-phase breakdowns.
//!
//! The simulator is a thin aggregation over the pipeline IR
//! ([`super::pipeline::PipelineSchedule`], the crate's single timing
//! source): per-resource busy totals, per-stage spans and the launch
//! critical path all come from the same lowered event schedule the
//! trace renderer and the serving engines consume.

use crate::model::config::SwinVariant;
use crate::model::graph::WorkloadGraph;

use super::control::Scheduler;
use super::pipeline::{PipelineSchedule, Resource};
use super::AccelConfig;

/// Result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub variant: &'static str,
    pub cfg: AccelConfig,
    pub total_cycles: u64,
    pub mmu_cycles: u64,
    pub nonlinear_cycles: u64,
    pub nonlinear_exposed: u64,
    pub mem_cycles: u64,
    /// SCU busy cycles (softmax share of `nonlinear_cycles`).
    pub scu_cycles: u64,
    /// GCU busy cycles (GELU share of `nonlinear_cycles`).
    pub gcu_cycles: u64,
    pub macs: u64,
    pub padded_macs: u64,
    pub per_stage_cycles: Vec<u64>,
    pub units: Vec<(String, u64)>,
}

impl SimResult {
    pub fn latency_ms(&self) -> f64 {
        self.cfg.cycles_to_ms(self.total_cycles)
    }

    pub fn fps(&self) -> f64 {
        1000.0 / self.latency_ms()
    }

    /// Throughput in GOPS, ops counted as 2 × MACs (the paper's
    /// convention — Table V's 431.2 GOPS = 2 × 4.48 GMAC × 48.1 FPS).
    pub fn gops(&self) -> f64 {
        2.0 * self.macs as f64 * self.fps() / 1e9
    }

    /// Fraction of MMU peak sustained over the inference.
    pub fn mmu_utilization(&self) -> f64 {
        self.macs as f64 / (self.total_cycles as f64 * self.cfg.mmu_macs_per_cycle() as f64)
    }

    /// Is the run bandwidth-bound (memory critical path ≥ compute)?
    pub fn memory_bound(&self) -> bool {
        self.mem_cycles >= self.mmu_cycles + self.nonlinear_exposed
    }
}

/// The simulator: variant + configuration → cycle-accurate-at-the-tile
/// timing via the pipeline schedule IR.
#[derive(Debug)]
pub struct Simulator {
    pub variant: &'static SwinVariant,
    pub cfg: AccelConfig,
    graph: WorkloadGraph,
}

impl Simulator {
    pub fn new(variant: &'static SwinVariant, cfg: AccelConfig) -> Self {
        Simulator {
            graph: WorkloadGraph::build(variant),
            variant,
            cfg,
        }
    }

    pub fn graph(&self) -> &WorkloadGraph {
        &self.graph
    }

    /// Lower the workload onto the pipeline IR (the same schedule every
    /// other timing consumer reads). Uses the simulator's own variant for
    /// the buffer model, so custom variants get plan-derived prefetch
    /// gating too.
    pub fn schedule(&self) -> PipelineSchedule {
        PipelineSchedule::lower_for(
            &self.graph,
            &Scheduler::new(self.cfg.clone()),
            Some(self.variant),
        )
    }

    /// Run the cycle model for one image.
    pub fn simulate_inference(&self) -> SimResult {
        self.aggregate(&self.schedule())
    }

    fn aggregate(&self, schedule: &PipelineSchedule) -> SimResult {
        let stages = self.variant.num_stages();
        // Exact stage attribution: every unit carries a real stage index
        // (the classifier head reports the last stage, standalone ops
        // their own); `stage_spans` asserts nothing needs clamping.
        let per_stage = schedule.stage_spans(stages, 1);
        let mut units = Vec::with_capacity(schedule.units.len());
        let mut prev = 0u64;
        for (u, sp) in schedule.units.iter().zip(schedule.placements(1)) {
            units.push((u.label.clone(), sp.compute_end - prev));
            prev = sp.compute_end;
        }
        let scu = schedule.busy(Resource::Scu);
        let gcu = schedule.busy(Resource::Gcu);
        SimResult {
            variant: self.variant.name,
            cfg: self.cfg.clone(),
            total_cycles: schedule.total_cycles,
            mmu_cycles: schedule.busy(Resource::Mmu),
            nonlinear_cycles: scu + gcu,
            nonlinear_exposed: schedule.units.iter().map(|u| u.nonlinear_exposed).sum(),
            mem_cycles: schedule.busy(Resource::Mru),
            scu_cycles: scu,
            gcu_cycles: gcu,
            macs: self.graph.total_macs(),
            padded_macs: self.graph.total_padded_macs(),
            per_stage_cycles: per_stage,
            units,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, MICRO, SMALL, TINY};

    fn sim(v: &'static SwinVariant) -> SimResult {
        Simulator::new(v, AccelConfig::paper()).simulate_inference()
    }

    #[test]
    fn tiny_fps_near_paper() {
        // Table V: Swin-T @ 48.1 FPS. Our model must land in the band
        // (the exact number depends on DDR efficiency we cannot measure).
        let r = sim(&TINY);
        let fps = r.fps();
        assert!((40.0..56.0).contains(&fps), "swin-t fps={fps}");
    }

    #[test]
    fn tiny_fps_band_holds_without_interunit_prefetch() {
        // the Table V calibration point: sequential scheduling units
        let r = Simulator::new(&TINY, AccelConfig::paper().sequential()).simulate_inference();
        let fps = r.fps();
        assert!((40.0..56.0).contains(&fps), "swin-t sequential fps={fps}");
    }

    #[test]
    fn small_fps_near_paper() {
        let r = sim(&SMALL);
        let fps = r.fps();
        assert!((22.0..31.0).contains(&fps), "swin-s fps={fps}");
    }

    #[test]
    fn base_fps_near_paper() {
        let r = sim(&BASE);
        let fps = r.fps();
        assert!((11.5..18.0).contains(&fps), "swin-b fps={fps}");
    }

    #[test]
    fn ordering_matches_paper() {
        // T > S > B in FPS; GOPS roughly flat (Table V: 431/436/403)
        let (t, s, b) = (sim(&TINY), sim(&SMALL), sim(&BASE));
        assert!(t.fps() > s.fps() && s.fps() > b.fps());
        let gmin = t.gops().min(s.gops()).min(b.gops());
        let gmax = t.gops().max(s.gops()).max(b.gops());
        assert!(gmax / gmin < 1.35, "GOPS spread {gmin}..{gmax}");
    }

    #[test]
    fn paper_design_is_memory_bound() {
        // weights are streamed per frame: the accelerator is DDR-bound,
        // which is exactly why FPS tracks parameter count across T/S/B
        for v in [&TINY, &SMALL, &BASE] {
            assert!(sim(v).memory_bound(), "{}", v.name);
        }
    }

    #[test]
    fn utilization_below_peak_but_sane() {
        let r = sim(&TINY);
        let u = r.mmu_utilization();
        assert!(u > 0.3 && u < 1.0, "util={u}");
    }

    #[test]
    fn micro_simulates() {
        let r = sim(&MICRO);
        assert!(r.fps() > 100.0, "micro should be fast: {}", r.fps());
        assert_eq!(r.per_stage_cycles.len(), 2);
    }

    #[test]
    fn stage_cycles_sum_to_total() {
        let r = sim(&TINY);
        assert_eq!(r.per_stage_cycles.iter().sum::<u64>(), r.total_cycles);
    }

    #[test]
    fn every_op_carries_an_in_range_stage() {
        // regression for the old `stage.min(stages - 1)` clamp: stage
        // indices must be exact for every op of every variant
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let stages = v.num_stages();
            for op in &WorkloadGraph::build(v).ops {
                assert!(op.stage < stages, "{}: op in stage {}", v.name, op.stage);
            }
        }
    }

    #[test]
    fn nonlinear_split_is_consistent() {
        let r = sim(&TINY);
        assert_eq!(r.scu_cycles + r.gcu_cycles, r.nonlinear_cycles);
        assert!(r.scu_cycles > 0 && r.gcu_cycles > 0);
    }

    #[test]
    fn unit_spans_sum_to_total() {
        let r = sim(&TINY);
        assert_eq!(r.units.iter().map(|(_, c)| c).sum::<u64>(), r.total_cycles);
    }
}
