//! Whole-model cycle simulation → the paper's Table V numbers
//! (FPS, GOPS, latency) and per-phase breakdowns.

use crate::model::config::SwinVariant;
use crate::model::graph::WorkloadGraph;

use super::control::{Scheduler, ScheduleUnit};
use super::AccelConfig;

/// Result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub variant: &'static str,
    pub cfg: AccelConfig,
    pub total_cycles: u64,
    pub mmu_cycles: u64,
    pub nonlinear_cycles: u64,
    pub nonlinear_exposed: u64,
    pub mem_cycles: u64,
    pub macs: u64,
    pub padded_macs: u64,
    pub per_stage_cycles: Vec<u64>,
    pub units: Vec<(String, u64)>,
}

impl SimResult {
    pub fn latency_ms(&self) -> f64 {
        self.cfg.cycles_to_ms(self.total_cycles)
    }

    pub fn fps(&self) -> f64 {
        1000.0 / self.latency_ms()
    }

    /// Throughput in GOPS, ops counted as 2 × MACs (the paper's
    /// convention — Table V's 431.2 GOPS = 2 × 4.48 GMAC × 48.1 FPS).
    pub fn gops(&self) -> f64 {
        2.0 * self.macs as f64 * self.fps() / 1e9
    }

    /// Fraction of MMU peak sustained over the inference.
    pub fn mmu_utilization(&self) -> f64 {
        self.macs as f64 / (self.total_cycles as f64 * self.cfg.mmu_macs_per_cycle() as f64)
    }

    /// Is the run bandwidth-bound (memory critical path ≥ compute)?
    pub fn memory_bound(&self) -> bool {
        self.mem_cycles >= self.mmu_cycles + self.nonlinear_exposed
    }
}

/// The simulator: variant + configuration → cycle-accurate-at-the-tile
/// timing via the control unit's schedule.
#[derive(Debug)]
pub struct Simulator {
    pub variant: &'static SwinVariant,
    pub cfg: AccelConfig,
    graph: WorkloadGraph,
}

impl Simulator {
    pub fn new(variant: &'static SwinVariant, cfg: AccelConfig) -> Self {
        Simulator {
            graph: WorkloadGraph::build(variant),
            variant,
            cfg,
        }
    }

    pub fn graph(&self) -> &WorkloadGraph {
        &self.graph
    }

    /// Run the cycle model for one image.
    pub fn simulate_inference(&self) -> SimResult {
        let scheduler = Scheduler::new(self.cfg.clone());
        let units = scheduler.schedule(&self.graph);
        self.aggregate(&units)
    }

    fn aggregate(&self, units: &[ScheduleUnit]) -> SimResult {
        let stages = self.variant.num_stages();
        let mut per_stage = vec![0u64; stages];
        let mut total = 0u64;
        let mut mmu = 0u64;
        let mut nl = 0u64;
        let mut nl_exposed = 0u64;
        let mut mem = 0u64;
        let mut unit_cycles = Vec::with_capacity(units.len());
        for u in units {
            let c = u.cycles();
            total += c;
            per_stage[u.stage.min(stages - 1)] += c;
            mmu += u.compute();
            nl += u.nonlinear();
            nl_exposed += u.nonlinear_exposed();
            mem += u.mem();
            unit_cycles.push((u.label.clone(), c));
        }
        SimResult {
            variant: self.variant.name,
            cfg: self.cfg.clone(),
            total_cycles: total,
            mmu_cycles: mmu,
            nonlinear_cycles: nl,
            nonlinear_exposed: nl_exposed,
            mem_cycles: mem,
            macs: self.graph.total_macs(),
            padded_macs: self.graph.total_padded_macs(),
            per_stage_cycles: per_stage,
            units: unit_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, MICRO, SMALL, TINY};

    fn sim(v: &'static SwinVariant) -> SimResult {
        Simulator::new(v, AccelConfig::paper()).simulate_inference()
    }

    #[test]
    fn tiny_fps_near_paper() {
        // Table V: Swin-T @ 48.1 FPS. Our model must land in the band
        // (the exact number depends on DDR efficiency we cannot measure).
        let r = sim(&TINY);
        let fps = r.fps();
        assert!((40.0..56.0).contains(&fps), "swin-t fps={fps}");
    }

    #[test]
    fn small_fps_near_paper() {
        let r = sim(&SMALL);
        let fps = r.fps();
        assert!((22.0..31.0).contains(&fps), "swin-s fps={fps}");
    }

    #[test]
    fn base_fps_near_paper() {
        let r = sim(&BASE);
        let fps = r.fps();
        assert!((11.5..18.0).contains(&fps), "swin-b fps={fps}");
    }

    #[test]
    fn ordering_matches_paper() {
        // T > S > B in FPS; GOPS roughly flat (Table V: 431/436/403)
        let (t, s, b) = (sim(&TINY), sim(&SMALL), sim(&BASE));
        assert!(t.fps() > s.fps() && s.fps() > b.fps());
        let gmin = t.gops().min(s.gops()).min(b.gops());
        let gmax = t.gops().max(s.gops()).max(b.gops());
        assert!(gmax / gmin < 1.35, "GOPS spread {gmin}..{gmax}");
    }

    #[test]
    fn paper_design_is_memory_bound() {
        // weights are streamed per frame: the accelerator is DDR-bound,
        // which is exactly why FPS tracks parameter count across T/S/B
        for v in [&TINY, &SMALL, &BASE] {
            assert!(sim(v).memory_bound(), "{}", v.name);
        }
    }

    #[test]
    fn utilization_below_peak_but_sane() {
        let r = sim(&TINY);
        let u = r.mmu_utilization();
        assert!(u > 0.3 && u < 1.0, "util={u}");
    }

    #[test]
    fn micro_simulates() {
        let r = sim(&MICRO);
        assert!(r.fps() > 100.0, "micro should be fast: {}", r.fps());
        assert_eq!(r.per_stage_cycles.len(), 2);
    }

    #[test]
    fn stage_cycles_sum_to_total() {
        let r = sim(&TINY);
        assert_eq!(r.per_stage_cycles.iter().sum::<u64>(), r.total_cycles);
    }
}
