//! Tilers and data-layout transforms (paper Figs. 4–5): an `IntMat`
//! row-major integer matrix, MMU tile padding (the DSU's zero-expansion,
//! §IV.B), the PatchEmbed im2col flattening and the window
//! partition/reverse/roll transforms shared by the functional simulator.

use crate::model::graph::{TILE_K, TILE_M, TILE_N};

/// Row-major i32 matrix (the functional datapath's tensor type).
#[derive(Debug, Clone, PartialEq)]
pub struct IntMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl IntMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        IntMat {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<i32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        IntMat { rows, cols, data }
    }

    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    #[inline(always)]
    pub fn set(&mut self, r: usize, c: usize, v: i32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[i32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Zero-pad to (rows_to, cols_to) — the DSU's expansion (paper §V.A).
    pub fn pad_to(&self, rows_to: usize, cols_to: usize) -> IntMat {
        assert!(rows_to >= self.rows && cols_to >= self.cols);
        let mut out = IntMat::zeros(rows_to, cols_to);
        for r in 0..self.rows {
            out.data[r * cols_to..r * cols_to + self.cols]
                .copy_from_slice(self.row(r));
        }
        out
    }

    /// Slice the top-left (rows_to × cols_to) block back out.
    pub fn crop(&self, rows_to: usize, cols_to: usize) -> IntMat {
        assert!(rows_to <= self.rows && cols_to <= self.cols);
        let mut out = IntMat::zeros(rows_to, cols_to);
        for r in 0..rows_to {
            out.data[r * cols_to..(r + 1) * cols_to]
                .copy_from_slice(&self.row(r)[..cols_to]);
        }
        out
    }

    pub fn transpose(&self) -> IntMat {
        let mut out = IntMat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.set(c, r, self.at(r, c));
            }
        }
        out
    }
}

pub fn pad_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// MMU alignment targets for a GEMM (rows → M²·k, k → c_i, n → c_o).
pub fn mmu_padded_shape(rows: usize, k: usize, n: usize) -> (usize, usize, usize) {
    (pad_up(rows, TILE_M), pad_up(k, TILE_K), pad_up(n, TILE_N))
}

/// A (H, W, C) integer feature map (token grid between blocks).
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureMap {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i32>,
}

impl FeatureMap {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        FeatureMap {
            h,
            w,
            c,
            data: vec![0; h * w * c],
        }
    }

    #[inline(always)]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> i32 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline(always)]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: i32) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }

    /// Flatten to a (H·W × C) token matrix (row-major scan order —
    /// identical to jnp reshape).
    pub fn to_tokens(&self) -> IntMat {
        IntMat::from_vec(self.h * self.w, self.c, self.data.clone())
    }

    pub fn from_tokens(t: &IntMat, h: usize, w: usize) -> Self {
        assert_eq!(t.rows, h * w);
        FeatureMap {
            h,
            w,
            c: t.cols,
            data: t.data.clone(),
        }
    }

    /// Cyclic roll by (dy, dx) — `jnp.roll(x, (dy, dx), axis=(0, 1))`.
    pub fn roll(&self, dy: isize, dx: isize) -> FeatureMap {
        let mut out = FeatureMap::zeros(self.h, self.w, self.c);
        let h = self.h as isize;
        let w = self.w as isize;
        for y in 0..self.h {
            for x in 0..self.w {
                let sy = ((y as isize - dy).rem_euclid(h)) as usize;
                let sx = ((x as isize - dx).rem_euclid(w)) as usize;
                for ch in 0..self.c {
                    out.set(y, x, ch, self.at(sy, sx, ch));
                }
            }
        }
        out
    }

    /// Window partition: (H, W, C) → per-window (M² × C) matrices, window
    /// scan order = (row-major window grid), matching
    /// `model.window_partition`.
    pub fn window_partition(&self, m: usize) -> Vec<IntMat> {
        assert!(self.h % m == 0 && self.w % m == 0);
        let gw = self.w / m;
        let gh = self.h / m;
        let mut wins = Vec::with_capacity(gh * gw);
        for wy in 0..gh {
            for wx in 0..gw {
                let mut mat = IntMat::zeros(m * m, self.c);
                for iy in 0..m {
                    for ix in 0..m {
                        for ch in 0..self.c {
                            mat.set(
                                iy * m + ix,
                                ch,
                                self.at(wy * m + iy, wx * m + ix, ch),
                            );
                        }
                    }
                }
                wins.push(mat);
            }
        }
        wins
    }

    /// Inverse of [`Self::window_partition`].
    pub fn window_reverse(wins: &[IntMat], m: usize, h: usize, w: usize) -> FeatureMap {
        let c = wins[0].cols;
        let gw = w / m;
        let mut out = FeatureMap::zeros(h, w, c);
        for (wi, win) in wins.iter().enumerate() {
            let wy = wi / gw;
            let wx = wi % gw;
            for iy in 0..m {
                for ix in 0..m {
                    for ch in 0..c {
                        out.set(wy * m + iy, wx * m + ix, ch, win.at(iy * m + ix, ch));
                    }
                }
            }
        }
        out
    }

    /// Patch-merging concat: 2×2 neighbourhood → 4C channels at half
    /// resolution, channel order matching the jnp
    /// `reshape→transpose→reshape` in `model.forward_*`:
    /// out[.., (iy*2+ix)*C + ch] = in[2y+iy, 2x+ix, ch].
    pub fn merge_2x2(&self) -> FeatureMap {
        assert!(self.h % 2 == 0 && self.w % 2 == 0);
        let mut out = FeatureMap::zeros(self.h / 2, self.w / 2, 4 * self.c);
        for y in 0..self.h / 2 {
            for x in 0..self.w / 2 {
                for iy in 0..2 {
                    for ix in 0..2 {
                        for ch in 0..self.c {
                            out.set(
                                y,
                                x,
                                (iy * 2 + ix) * self.c + ch,
                                self.at(2 * y + iy, 2 * x + ix, ch),
                            );
                        }
                    }
                }
            }
        }
        out
    }
}

/// PatchEmbed im2col (paper Fig. 5): (H, W, 3) image → (H/p · W/p) × (p²·3)
/// patch-vector matrix, flattened in (py, px, chan) order — identical to
/// `model.patch_embed_tokens`.
pub fn patch_embed_tokens(img: &FeatureMap, p: usize) -> IntMat {
    assert!(img.h % p == 0 && img.w % p == 0);
    let gh = img.h / p;
    let gw = img.w / p;
    let k = p * p * img.c;
    let mut out = IntMat::zeros(gh * gw, k);
    for gy in 0..gh {
        for gx in 0..gw {
            let row = gy * gw + gx;
            let mut col = 0;
            for py in 0..p {
                for px in 0..p {
                    for ch in 0..img.c {
                        out.set(row, col, img.at(gy * p + py, gx * p + px, ch));
                        col += 1;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iota_map(h: usize, w: usize, c: usize) -> FeatureMap {
        let mut f = FeatureMap::zeros(h, w, c);
        for (i, v) in f.data.iter_mut().enumerate() {
            *v = i as i32;
        }
        f
    }

    #[test]
    fn window_roundtrip() {
        let f = iota_map(14, 14, 3);
        let wins = f.window_partition(7);
        assert_eq!(wins.len(), 4);
        let back = FeatureMap::window_reverse(&wins, 7, 14, 14);
        assert_eq!(back, f);
    }

    #[test]
    fn window_contents_local() {
        let f = iota_map(14, 14, 1);
        let wins = f.window_partition(7);
        // first window = top-left 7×7 patch
        for iy in 0..7 {
            for ix in 0..7 {
                assert_eq!(wins[0].at(iy * 7 + ix, 0), f.at(iy, ix, 0));
            }
        }
    }

    #[test]
    fn roll_matches_numpy_semantics() {
        let f = iota_map(4, 4, 1);
        let r = f.roll(-1, -1); // np.roll(x, (-1,-1), (0,1))
        // out[y][x] = in[(y+1)%4][(x+1)%4]
        assert_eq!(r.at(0, 0, 0), f.at(1, 1, 0));
        assert_eq!(r.at(3, 3, 0), f.at(0, 0, 0));
        let rr = r.roll(1, 1);
        assert_eq!(rr, f);
    }

    #[test]
    fn merge_2x2_layout() {
        let f = iota_map(4, 4, 2);
        let m = f.merge_2x2();
        assert_eq!((m.h, m.w, m.c), (2, 2, 8));
        // out[0,0,(iy*2+ix)*2+ch] == f[iy, ix, ch]
        for iy in 0..2 {
            for ix in 0..2 {
                for ch in 0..2 {
                    assert_eq!(m.at(0, 0, (iy * 2 + ix) * 2 + ch), f.at(iy, ix, ch));
                }
            }
        }
    }

    #[test]
    fn patch_embed_flatten_order() {
        let img = iota_map(8, 8, 3);
        let t = patch_embed_tokens(&img, 4);
        assert_eq!((t.rows, t.cols), (4, 48));
        // first row: the top-left 4×4 patch in (py, px, ch) scan order
        let mut col = 0;
        for py in 0..4 {
            for px in 0..4 {
                for ch in 0..3 {
                    assert_eq!(t.at(0, col), img.at(py, px, ch));
                    col += 1;
                }
            }
        }
    }

    #[test]
    fn pad_and_crop_roundtrip() {
        let mut a = IntMat::zeros(3, 5);
        for (i, v) in a.data.iter_mut().enumerate() {
            *v = i as i32 + 1;
        }
        let p = a.pad_to(49, 32);
        assert_eq!(p.rows, 49);
        assert_eq!(p.at(2, 4), a.at(2, 4));
        assert_eq!(p.at(3, 0), 0);
        assert_eq!(p.crop(3, 5), a);
    }

    #[test]
    fn transpose_involution() {
        let mut a = IntMat::zeros(3, 4);
        for (i, v) in a.data.iter_mut().enumerate() {
            *v = (i * i) as i32;
        }
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), a.at(1, 2));
    }

    #[test]
    fn mmu_padded_shapes() {
        assert_eq!(mmu_padded_shape(49, 32, 49), (49, 32, 64));
        assert_eq!(mmu_padded_shape(196, 48, 32), (196, 64, 32));
        assert_eq!(mmu_padded_shape(1, 64, 10), (49, 64, 32));
    }
}
