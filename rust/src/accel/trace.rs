//! Cycle-timeline tracing: turns the control unit's schedule into an
//! event timeline (per unit: MMU / MRU-MWU / SCU / GCU), exportable as
//! Chrome-trace JSON (`chrome://tracing`, Perfetto) for visual inspection
//! of the overlap structure the cycle model assumes.

use std::fmt::Write as _;

use crate::model::config::SwinVariant;
use crate::model::graph::{OpKind, WorkloadGraph};

use super::control::Scheduler;
use super::AccelConfig;

/// Which hardware unit an event occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    Mmu,
    Memory,
    Scu,
    Gcu,
}

impl Unit {
    pub fn name(self) -> &'static str {
        match self {
            Unit::Mmu => "MMU",
            Unit::Memory => "MRU/MWU",
            Unit::Scu => "SCU",
            Unit::Gcu => "GCU",
        }
    }
}

/// One timeline event, in cycles.
#[derive(Debug, Clone)]
pub struct Event {
    pub unit: Unit,
    pub label: String,
    pub start: u64,
    pub end: u64,
}

impl Event {
    pub fn dur(&self) -> u64 {
        self.end - self.start
    }
}

/// The full timeline of one inference.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub variant: &'static str,
    pub events: Vec<Event>,
    pub total_cycles: u64,
}

impl Timeline {
    /// Build the timeline by replaying the scheduler's units: within each
    /// unit compute and memory start together (double buffering); the
    /// nonlinear engines run pipelined behind the MMU.
    pub fn capture(variant: &'static SwinVariant, cfg: AccelConfig) -> Timeline {
        let graph = WorkloadGraph::build(variant);
        let scheduler = Scheduler::new(cfg);
        let units = scheduler.schedule(&graph);

        let mut events = Vec::new();
        let mut clock = 0u64;
        let mut op_iter = graph.ops.iter();
        for u in &units {
            let unit_start = clock;
            let mut mmu_t = unit_start;
            let mut nl_t = unit_start;
            for timing in &u.timings {
                let op = op_iter.next().expect("schedule/graph mismatch");
                let label = format!("{}:{:?}", u.label, kind_name(&op.op));
                if timing.compute_cycles > 0 {
                    events.push(Event {
                        unit: Unit::Mmu,
                        label: label.clone(),
                        start: mmu_t,
                        end: mmu_t + timing.compute_cycles,
                    });
                    mmu_t += timing.compute_cycles;
                }
                if timing.nonlinear_exposed > 0 {
                    let unit = match op.op {
                        OpKind::Softmax { .. } => Unit::Scu,
                        _ => Unit::Gcu,
                    };
                    let start = mmu_t.max(nl_t);
                    events.push(Event {
                        unit,
                        label,
                        start,
                        end: start + timing.nonlinear_cycles.max(1),
                    });
                    nl_t = start + timing.nonlinear_exposed;
                    mmu_t += timing.nonlinear_exposed;
                }
            }
            let mem = u.mem();
            if mem > 0 {
                events.push(Event {
                    unit: Unit::Memory,
                    label: format!("{}:stream", u.label),
                    start: unit_start,
                    end: unit_start + mem,
                });
            }
            clock = unit_start + u.cycles();
        }
        Timeline {
            variant: variant.name,
            events,
            total_cycles: clock,
        }
    }

    /// Busy cycles per unit (for utilisation summaries).
    pub fn busy(&self, unit: Unit) -> u64 {
        self.events
            .iter()
            .filter(|e| e.unit == unit)
            .map(Event::dur)
            .sum()
    }

    /// Utilisation of a unit over the whole inference.
    pub fn utilisation(&self, unit: Unit) -> f64 {
        self.busy(unit) as f64 / self.total_cycles.max(1) as f64
    }

    /// Chrome-trace JSON (one "thread" per hardware unit; µs = cycles).
    pub fn to_chrome_trace(&self) -> String {
        let mut s = String::from("[");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let tid = match e.unit {
                Unit::Mmu => 1,
                Unit::Memory => 2,
                Unit::Scu => 3,
                Unit::Gcu => 4,
            };
            let _ = write!(
                s,
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
                e.label.replace('"', ""),
                e.start,
                e.dur().max(1),
                tid
            );
        }
        s.push(']');
        s
    }
}

fn kind_name(op: &OpKind) -> &'static str {
    match op {
        OpKind::Gemm { kind, .. } => match kind {
            crate::model::graph::GemmKind::PatchEmbed => "patch_embed",
            crate::model::graph::GemmKind::Qkv => "qkv",
            crate::model::graph::GemmKind::Scores => "scores",
            crate::model::graph::GemmKind::AttnV => "attn_v",
            crate::model::graph::GemmKind::Proj => "proj",
            crate::model::graph::GemmKind::Mlp1 => "mlp1",
            crate::model::graph::GemmKind::Mlp2 => "mlp2",
            crate::model::graph::GemmKind::PatchMerge => "merge",
            crate::model::graph::GemmKind::Head => "head",
        },
        OpKind::Softmax { .. } => "softmax",
        OpKind::Gelu { .. } => "gelu",
        OpKind::Add { .. } => "add",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{MICRO, TINY};
    use crate::util::json::Json;

    #[test]
    fn timeline_total_matches_simulator() {
        use crate::accel::sim::Simulator;
        for v in [&MICRO, &TINY] {
            let t = Timeline::capture(v, AccelConfig::paper());
            let r = Simulator::new(v, AccelConfig::paper()).simulate_inference();
            assert_eq!(t.total_cycles, r.total_cycles, "{}", v.name);
        }
    }

    #[test]
    fn events_are_well_formed() {
        let t = Timeline::capture(&MICRO, AccelConfig::paper());
        assert!(!t.events.is_empty());
        for e in &t.events {
            assert!(e.end >= e.start);
            assert!(e.end <= t.total_cycles + 1);
        }
    }

    #[test]
    fn mmu_busy_equals_compute_cycles() {
        use crate::accel::sim::Simulator;
        let t = Timeline::capture(&TINY, AccelConfig::paper());
        let r = Simulator::new(&TINY, AccelConfig::paper()).simulate_inference();
        assert_eq!(t.busy(Unit::Mmu), r.mmu_cycles);
        assert_eq!(t.busy(Unit::Memory), r.mem_cycles);
    }

    #[test]
    fn memory_utilisation_dominates_for_paper_design() {
        let t = Timeline::capture(&TINY, AccelConfig::paper());
        assert!(t.utilisation(Unit::Memory) > t.utilisation(Unit::Mmu));
        assert!(t.utilisation(Unit::Memory) > 0.8);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let t = Timeline::capture(&MICRO, AccelConfig::paper());
        let j = Json::parse(&t.to_chrome_trace()).expect("valid json");
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), t.events.len());
        assert!(arr[0].get("ts").is_some());
    }
}
