//! Cycle-timeline tracing: renders the pipeline IR's event schedule
//! ([`super::pipeline::PipelineSchedule`]) as a per-unit timeline
//! (MMU / MRU-MWU / SCU / GCU), exportable as Chrome-trace JSON
//! (`chrome://tracing`, Perfetto) for visual inspection of the overlap
//! structure the cycle model *actually* uses — the renderer re-derives
//! nothing.

use std::fmt::Write as _;

use crate::model::config::SwinVariant;

use super::pipeline::PipelineSchedule;
use super::shard::ShardedSchedule;
use super::AccelConfig;

pub use super::pipeline::{Resource as Unit, Segment as Event};

/// Chrome-trace thread id of a hardware unit (stable across pids).
fn unit_tid(u: Unit) -> u32 {
    match u {
        Unit::Mmu => 1,
        Unit::Mru => 2,
        Unit::Scu => 3,
        Unit::Gcu => 4,
        Unit::Link => 5,
    }
}

/// The full timeline of one launch.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub variant: &'static str,
    pub events: Vec<Event>,
    pub total_cycles: u64,
}

impl Timeline {
    /// Build the timeline for a single-image launch of `variant`.
    pub fn capture(variant: &'static SwinVariant, cfg: AccelConfig) -> Timeline {
        Timeline::from_schedule(&PipelineSchedule::for_variant(variant, cfg), 1)
    }

    /// Render a batch-`batch` launch of an existing schedule.
    pub fn from_schedule(schedule: &PipelineSchedule, batch: usize) -> Timeline {
        Timeline {
            variant: schedule.variant,
            events: schedule.segments(batch),
            total_cycles: schedule.launch_cycles(batch),
        }
    }

    /// Render a back-to-back launch sequence (the launch-sequence IR):
    /// every launch's events at their absolute spans, labels prefixed
    /// `L<j>:`. Each launch streams its own weights — launch 0's stream
    /// segments are never re-emitted for launches 1..N.
    pub fn from_sequence(schedule: &PipelineSchedule, batches: &[usize]) -> Timeline {
        let seq = schedule.sequence(batches);
        Timeline {
            variant: schedule.variant,
            events: schedule.sequence_segments(&seq),
            total_cycles: seq.total_cycles,
        }
    }

    /// Busy cycles per unit (for utilisation summaries).
    pub fn busy(&self, unit: Unit) -> u64 {
        self.events
            .iter()
            .filter(|e| e.unit == unit)
            .map(Event::dur)
            .sum()
    }

    /// Utilisation of a unit over the whole launch.
    pub fn utilisation(&self, unit: Unit) -> f64 {
        self.busy(unit) as f64 / self.total_cycles.max(1) as f64
    }

    /// Chrome-trace JSON (one "thread" per hardware unit; µs = cycles).
    pub fn to_chrome_trace(&self) -> String {
        let mut s = String::from("[");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let tid = unit_tid(e.unit);
            let _ = write!(
                s,
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
                e.label.replace('"', ""),
                e.start,
                e.dur().max(1),
                tid
            );
        }
        s.push(']');
        s
    }
}

/// The timeline of a *sharded* pipeline sequence: every card's events
/// plus the inter-card link transfers, on one absolute timeline. In the
/// Chrome-trace export each card is its own process (`pid = shard + 1`)
/// so its four engine tracks group visually; a link transfer is
/// attributed to the *upstream* card's egress (its `tid 5` track).
#[derive(Debug, Clone)]
pub struct ShardedTimeline {
    pub variant: &'static str,
    /// `(shard index, event)` — link k's transfers carry shard index k.
    pub events: Vec<(usize, Event)>,
    pub total_cycles: u64,
}

impl ShardedTimeline {
    /// Render a back-to-back sharded launch sequence.
    pub fn from_sequence(schedule: &ShardedSchedule, batches: &[usize]) -> ShardedTimeline {
        let seq = schedule.sequence(batches);
        let mut events = Vec::new();
        for k in 0..schedule.cards() {
            events.extend(
                schedule
                    .shard_segments(&seq, k)
                    .into_iter()
                    .map(|e| (k, e)),
            );
            if k + 1 < schedule.cards() {
                events.extend(schedule.link_segments(&seq, k).into_iter().map(|e| (k, e)));
            }
        }
        ShardedTimeline {
            variant: seq.variant,
            events,
            total_cycles: seq.total_cycles,
        }
    }

    /// Busy cycles of one unit on one card (links live on the upstream
    /// card's index).
    pub fn busy(&self, shard: usize, unit: Unit) -> u64 {
        self.events
            .iter()
            .filter(|(k, e)| *k == shard && e.unit == unit)
            .map(|(_, e)| e.dur())
            .sum()
    }

    /// Chrome-trace JSON: one process per card, one thread per unit.
    pub fn to_chrome_trace(&self) -> String {
        let mut s = String::from("[");
        for (i, (k, e)) in self.events.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\"tid\":{}}}",
                e.label.replace('"', ""),
                e.start,
                e.dur().max(1),
                k + 1,
                unit_tid(e.unit)
            );
        }
        s.push(']');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE_384, MICRO, TINY};
    use crate::util::json::Json;

    #[test]
    fn timeline_total_matches_simulator() {
        use crate::accel::sim::Simulator;
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            for v in [&MICRO, &TINY] {
                let t = Timeline::capture(v, cfg.clone());
                let r = Simulator::new(v, cfg.clone()).simulate_inference();
                assert_eq!(t.total_cycles, r.total_cycles, "{}", v.name);
            }
        }
    }

    #[test]
    fn events_are_well_formed() {
        let t = Timeline::capture(&MICRO, AccelConfig::paper());
        assert!(!t.events.is_empty());
        for e in &t.events {
            assert!(e.end >= e.start);
            assert!(e.end <= t.total_cycles + 1);
        }
    }

    #[test]
    fn busy_cycles_equal_sim_result_per_resource() {
        // the single-timing-source invariant: the trace and the simulator
        // read the same schedule, so per-resource busy totals must agree
        use crate::accel::sim::Simulator;
        for cfg in [AccelConfig::paper(), AccelConfig::paper().sequential()] {
            let t = Timeline::capture(&TINY, cfg.clone());
            let r = Simulator::new(&TINY, cfg).simulate_inference();
            assert_eq!(t.busy(Unit::Mmu), r.mmu_cycles);
            assert_eq!(t.busy(Unit::Mru), r.mem_cycles);
            assert_eq!(t.busy(Unit::Scu), r.scu_cycles);
            assert_eq!(t.busy(Unit::Gcu), r.gcu_cycles);
        }
    }

    #[test]
    fn memory_utilisation_dominates_for_paper_design() {
        let t = Timeline::capture(&TINY, AccelConfig::paper());
        assert!(t.utilisation(Unit::Mru) > t.utilisation(Unit::Mmu));
        assert!(t.utilisation(Unit::Mru) > 0.8);
    }

    #[test]
    fn batched_timeline_replays_compute_once_per_image() {
        let s = PipelineSchedule::for_variant(&MICRO, AccelConfig::paper());
        let t1 = Timeline::from_schedule(&s, 1);
        let t4 = Timeline::from_schedule(&s, 4);
        assert_eq!(t4.busy(Unit::Mmu), 4 * t1.busy(Unit::Mmu));
        assert_eq!(t4.busy(Unit::Mru), t1.busy(Unit::Mru)); // stream shared
        assert_eq!(t4.total_cycles, s.launch_cycles(4));
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let t = Timeline::capture(&MICRO, AccelConfig::paper());
        let j = Json::parse(&t.to_chrome_trace()).expect("valid json");
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), t.events.len());
        assert!(arr[0].get("ts").is_some());
    }

    #[test]
    fn multi_launch_timeline_streams_once_per_launch() {
        // regression: the multi-launch renderer must emit each launch's
        // own stream segments (at that launch's offsets), not re-emit
        // launch 0's — segment counts in the Chrome-trace export are the
        // per-launch counts times the launch count, exactly
        let s = PipelineSchedule::for_variant(&MICRO, AccelConfig::paper());
        let one = Timeline::from_schedule(&s, 2);
        let three = Timeline::from_sequence(&s, &[2, 2, 2]);
        assert_eq!(three.events.len(), 3 * one.events.len());
        assert_eq!(three.busy(Unit::Mru), 3 * one.busy(Unit::Mru));
        assert_eq!(three.busy(Unit::Mmu), 3 * one.busy(Unit::Mmu));
        assert_eq!(three.total_cycles, s.sequence_cycles(&[2, 2, 2]));
        // chrome trace carries every event exactly once
        let j = Json::parse(&three.to_chrome_trace()).expect("valid json");
        assert_eq!(j.as_arr().unwrap().len(), three.events.len());
        // and the launch prefixes are distinct per launch
        for pre in ["L0:", "L1:", "L2:"] {
            assert!(
                three.events.iter().any(|e| e.label.starts_with(pre)),
                "missing {pre}"
            );
        }
    }

    #[test]
    fn sharded_timeline_tracks_cards_and_links() {
        use crate::accel::shard::ShardedSchedule;
        let s = ShardedSchedule::for_variant(&BASE_384, AccelConfig::paper());
        let t = ShardedTimeline::from_sequence(&s, &[2, 2]);
        assert_eq!(t.variant, "swin-b-384");
        assert_eq!(t.total_cycles, s.sequence_cycles(&[2, 2]));
        // both cards busy, link transfers attributed upstream
        assert!(t.busy(0, Unit::Mmu) > 0);
        assert!(t.busy(1, Unit::Mmu) > 0);
        assert!(t.busy(0, Unit::Link) > 0);
        assert_eq!(t.busy(1, Unit::Link), 0);
        // chrome export is valid json with per-card pids
        let j = Json::parse(&t.to_chrome_trace()).expect("valid json");
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), t.events.len());
        let pids: std::collections::BTreeSet<usize> = arr
            .iter()
            .map(|e| e.get("pid").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(pids, [1usize, 2].into_iter().collect());
    }
}
