//! LOD + DU: log-domain division (paper Eqs. 11–12, Fig. 9).
//!
//! `F = m·2^w` with `m ∈ [1,2)` found by a Leading-One Detector; then
//! `log₂F ≈ w + (m − 1)` (max error 0.0861 at m = 1/ln2) and
//! `F₁/F₂ ≈ 2^(log₂F₁ − log₂F₂)` evaluated by the EU.
//!
//! Bit-identical to `fixedpoint.{lod, log2_approx, div_exponent}`.

use crate::fixed::{EXP_FRAC, OUT_FRAC};

/// Leading-one detector: bit index of the MSB of `f` (> 0); 0 for f <= 0.
/// Branch-free binary search, mirroring the jnp implementation (and the
/// hardware priority encoder).
#[inline(always)]
pub fn lod(mut f: i32) -> i32 {
    let mut n = 0;
    // identical structure to fixedpoint.lod: 16/8/4/2/1 probes
    for sh in [16, 8, 4, 2, 1] {
        if f >= (1 << sh) {
            n += sh;
            f >>= sh;
        }
    }
    n
}

/// `log₂(f) ≈ w + (m − 1)` in Q*.EXP_FRAC for `f > 0` with `frac`
/// fractional bits.
#[inline]
pub fn log2_approx(f: i32, frac: u32) -> i32 {
    let pos = lod(f);
    let w = pos - frac as i32;
    // normalise mantissa so its MSB sits at bit OUT_FRAC
    let sh = pos - OUT_FRAC as i32;
    let m = if sh >= 0 { f >> sh } else { f << (-sh) };
    let frac_part = (m - (1 << OUT_FRAC)) >> (OUT_FRAC - EXP_FRAC);
    (w << EXP_FRAC) + frac_part
}

/// DU output: exponent of `num/den` in Q*.EXP_FRAC (`num`, `den` > 0).
#[inline]
pub fn div_exponent(num: i32, num_frac: u32, den: i32, den_frac: u32) -> i32 {
    log2_approx(num, num_frac) - log2_approx(den, den_frac)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::EXP_FRAC;

    #[test]
    fn lod_known_values() {
        assert_eq!(lod(1), 0);
        assert_eq!(lod(2), 1);
        assert_eq!(lod(3), 1);
        assert_eq!(lod(4), 2);
        assert_eq!(lod(255), 7);
        assert_eq!(lod(256), 8);
        assert_eq!(lod((1 << 30) - 1), 29);
        assert_eq!(lod(1 << 30), 30);
        assert_eq!(lod(0), 0);
    }

    #[test]
    fn lod_matches_bit_length_exhaustive_sample() {
        let mut f = 1i64;
        while f < (1i64 << 31) {
            let v = f as i32;
            if v > 0 {
                assert_eq!(lod(v), 31 - v.leading_zeros() as i32);
            }
            f = f * 3 + 1;
        }
    }

    #[test]
    fn log2_powers_exact() {
        for k in 0..20 {
            assert_eq!(log2_approx(1 << k, 0), k << EXP_FRAC);
        }
    }

    #[test]
    fn log2_error_bound() {
        // Eq. 12 intrinsic bound: |log2(m) - (m-1)| <= 0.0861
        let mut max_err: f64 = 0.0;
        let mut f = 3i32;
        while f < (1 << 22) {
            let got = log2_approx(f, 0) as f64 / (1 << EXP_FRAC) as f64;
            let want = (f as f64).log2();
            max_err = max_err.max((got - want).abs());
            f = f.wrapping_mul(7) / 5 + 1;
        }
        assert!(max_err < 0.0875, "max_err={max_err}");
    }

    #[test]
    fn division_quotient_bound() {
        // quotient within 2^±0.18 of true (two log errors + EU PWL)
        let cases = [(100, 7), (65536, 3), (12345, 678), (5, 4), (1, 1000)];
        for (a, b) in cases {
            let e = div_exponent(a, 0, b, 0);
            // evaluate 2^e in float: the DU's output is the exponent; the
            // EU's shift clamp is a separate (range) concern
            let got = 2f64.powf(e as f64 / (1 << EXP_FRAC) as f64);
            let want = a as f64 / b as f64;
            let ratio = got / want;
            assert!(
                ratio < 2f64.powf(0.18) && ratio > 2f64.powf(-0.18),
                "a={a} b={b} got={got} want={want}"
            );
        }
    }

    #[test]
    fn frac_scaling_cancels_in_ratio() {
        // same value expressed at different fracs: exponent must shift by
        // exactly the frac delta
        let e1 = log2_approx(1 << 14, 14);
        let e2 = log2_approx(1 << 10, 10);
        assert_eq!(e1, e2);
    }
}
