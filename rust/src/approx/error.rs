//! Approximation-error analysis for every hardware shortcut the paper
//! takes (§III.B). The paper asserts acceptability; this module measures
//! it, and sweeps the designable knobs (PWL segment count, constant
//! precision) the `nonlinear_units` bench reports.

use super::division::log2_approx;
use super::exp2::exp2_fixed;
use super::gelu::{gelu_exact_f64, gelu_fixed};
use super::softmax::softmax_row;
use crate::fixed::{quantize, DATA_FRAC, EXP_FRAC, OUT_FRAC, PROB_FRAC};
use crate::util::prng::Rng;

/// Max relative error of the EU's 2^v over v ∈ [lo, hi] (float, sampled).
pub fn exp2_max_rel_error(lo: f64, hi: f64, samples: usize) -> f64 {
    let mut max_rel = 0f64;
    for i in 0..samples {
        let v = lo + (hi - lo) * i as f64 / (samples - 1) as f64;
        let vq = (v * (1 << EXP_FRAC) as f64).round() as i32;
        let got = exp2_fixed(vq, OUT_FRAC) as f64 / (1 << OUT_FRAC) as f64;
        let want = 2f64.powf(vq as f64 / (1 << EXP_FRAC) as f64);
        if want > 1e-4 {
            max_rel = max_rel.max((got - want).abs() / want);
        }
    }
    max_rel
}

/// Max |log2(f) − approx| over a range (the Eq. 12 intrinsic bound
/// |log2 m − (m−1)| ≤ 0.0861).
pub fn log2_max_abs_error(samples: usize) -> f64 {
    let mut max_err = 0f64;
    let mut f = 3i64;
    let mut count = 0;
    while count < samples && f < (1 << 30) {
        let got = log2_approx(f as i32, 0) as f64 / (1 << EXP_FRAC) as f64;
        let want = (f as f64).log2();
        max_err = max_err.max((got - want).abs());
        f = f * 11 / 7 + 1;
        count += 1;
    }
    max_err
}

/// Softmax error stats over random logit rows: (max abs prob error,
/// max |row sum − 1|).
pub fn softmax_error_stats(rows: usize, width: usize, sigma: f64, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut max_err = 0f64;
    let mut max_sum_dev = 0f64;
    let mut buf = vec![0i32; width];
    for _ in 0..rows {
        let xf: Vec<f64> = (0..width).map(|_| rng.normal() * sigma).collect();
        let xq: Vec<i32> = xf.iter().map(|&x| quantize(x as f32, DATA_FRAC)).collect();
        softmax_row(&xq, &mut buf);
        let m = xf.iter().cloned().fold(f64::MIN, f64::max);
        let e: Vec<f64> = xf.iter().map(|&v| (v - m).exp()).collect();
        let s: f64 = e.iter().sum();
        let mut rs = 0f64;
        for (q, ef) in buf.iter().zip(&e) {
            let p = *q as f64 / (1 << PROB_FRAC) as f64;
            max_err = max_err.max((p - ef / s).abs());
            rs += p;
        }
        max_sum_dev = max_sum_dev.max((rs - 1.0).abs());
    }
    (max_err, max_sum_dev)
}

/// GELU error stats over [lo, hi]: (max abs error, max rel error vs |y|≥0.25).
pub fn gelu_error_stats(lo: f64, hi: f64, step: f64, corrected: bool) -> (f64, f64) {
    let mut max_abs = 0f64;
    let mut max_rel = 0f64;
    let mut x = lo;
    while x <= hi {
        let q = quantize(x as f32, DATA_FRAC);
        let got = gelu_fixed(q, corrected) as f64 / 256.0;
        let want = gelu_exact_f64(x);
        max_abs = max_abs.max((got - want).abs());
        if want.abs() >= 0.25 {
            max_rel = max_rel.max((got - want).abs() / want.abs());
        }
        x += step;
    }
    (max_abs, max_rel)
}

/// Generic PWL-segment sweep: max relative error of an n-segment
/// endpoint-interpolated 2^f over [0,1) — justifies the paper's 8
/// segments (3 index bits).
pub fn pwl_exp2_error(segments: usize, samples: usize) -> f64 {
    let mut max_rel = 0f64;
    for i in 0..samples {
        let f = i as f64 / samples as f64;
        let s = ((f * segments as f64) as usize).min(segments - 1);
        let f0 = s as f64 / segments as f64;
        let f1 = (s + 1) as f64 / segments as f64;
        let y0 = 2f64.powf(f0);
        let y1 = 2f64.powf(f1);
        let approx = y0 + (y1 - y0) * (f - f0) / (f1 - f0);
        let want = 2f64.powf(f);
        max_rel = max_rel.max((approx - want).abs() / want);
    }
    max_rel
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eu_error_within_pwl_plus_quant_floor() {
        assert!(exp2_max_rel_error(-6.0, 6.0, 4001) < 8e-3);
        assert!(exp2_max_rel_error(0.0, 0.999, 2001) < 1.5e-3);
    }

    #[test]
    fn log2_error_at_eq12_bound() {
        let e = log2_max_abs_error(500);
        assert!(e < 0.0875, "e={e}");
        assert!(e > 0.07, "sweep should reach near the bound, e={e}");
    }

    #[test]
    fn softmax_errors_small_across_scales() {
        for sigma in [1.0, 3.0, 6.0] {
            let (max_err, sum_dev) = softmax_error_stats(100, 49, sigma, 9);
            assert!(max_err < 0.06, "sigma={sigma}: {max_err}");
            assert!(sum_dev < 0.16, "sigma={sigma}: {sum_dev}");
        }
    }

    #[test]
    fn gelu_corrected_constant_helps_midrange() {
        let (abs_p, _) = gelu_error_stats(1.0, 2.5, 0.01, false);
        let (abs_c, _) = gelu_error_stats(1.0, 2.5, 0.01, true);
        assert!(abs_c <= abs_p + 1e-9, "paper {abs_p} corrected {abs_c}");
    }

    #[test]
    fn pwl_error_quarters_per_doubling() {
        // PWL error ~ 1/segments²: each doubling cuts it ~4×
        let e4 = pwl_exp2_error(4, 4000);
        let e8 = pwl_exp2_error(8, 4000);
        let e16 = pwl_exp2_error(16, 4000);
        assert!(e4 / e8 > 3.0 && e4 / e8 < 5.0, "{}", e4 / e8);
        assert!(e8 / e16 > 3.0 && e8 / e16 < 5.0, "{}", e8 / e16);
        // the paper's 8 segments: ~9.4e-4 max rel — of the same order as
        // the Q10 exponent-input quantisation floor (ln2·2⁻¹⁰ ≈ 6.8e-4),
        // i.e. more segments would be wasted precision
        assert!(e8 < 1.2e-3, "{e8}");
        assert!(e8 > 8e-4, "{e8}");
    }
}
