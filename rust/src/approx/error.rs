//! Approximation-error analysis for every hardware shortcut the paper
//! takes (§III.B). The paper asserts acceptability; this module measures
//! it, and sweeps the designable knobs (PWL segment count, constant
//! precision) the `nonlinear_units` bench reports.

use super::division::log2_approx;
use super::exp2::exp2_fixed;
use super::gelu::{gelu_exact_f64, gelu_fixed};
use super::softmax::softmax_row;
use crate::fixed::{quantize, DATA_FRAC, EXP_FRAC, OUT_FRAC, PROB_FRAC};
use crate::util::prng::Rng;

/// Max relative error of the EU's 2^v over v ∈ [lo, hi] (float, sampled).
pub fn exp2_max_rel_error(lo: f64, hi: f64, samples: usize) -> f64 {
    let mut max_rel = 0f64;
    for i in 0..samples {
        let v = lo + (hi - lo) * i as f64 / (samples - 1) as f64;
        let vq = (v * (1 << EXP_FRAC) as f64).round() as i32;
        let got = exp2_fixed(vq, OUT_FRAC) as f64 / (1 << OUT_FRAC) as f64;
        let want = 2f64.powf(vq as f64 / (1 << EXP_FRAC) as f64);
        if want > 1e-4 {
            max_rel = max_rel.max((got - want).abs() / want);
        }
    }
    max_rel
}

/// Max |log2(f) − approx| over a range (the Eq. 12 intrinsic bound
/// |log2 m − (m−1)| ≤ 0.0861).
pub fn log2_max_abs_error(samples: usize) -> f64 {
    let mut max_err = 0f64;
    let mut f = 3i64;
    let mut count = 0;
    while count < samples && f < (1 << 30) {
        let got = log2_approx(f as i32, 0) as f64 / (1 << EXP_FRAC) as f64;
        let want = (f as f64).log2();
        max_err = max_err.max((got - want).abs());
        f = f * 11 / 7 + 1;
        count += 1;
    }
    max_err
}

/// Softmax accuracy statistics vs the f64 reference — the per-design
/// goldens `rust/tests/nonlinear_designs.rs` pins.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SoftmaxErrorStats {
    /// max |p_i − exact_i| over all elements of all rows
    pub max_err: f64,
    /// mean |p_i − exact_i| over all elements
    pub mean_err: f64,
    /// max |Σ_i p_i − 1| over rows
    pub max_sum_dev: f64,
}

/// GELU accuracy statistics vs the exact tanh reference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeluErrorStats {
    pub max_abs: f64,
    pub mean_abs: f64,
    /// max relative error where |exact| ≥ 0.25
    pub max_rel: f64,
}

/// Softmax error stats for an arbitrary row kernel (Q7.8 in → Q0.15
/// out) over random N(0, σ²) logit rows — design-generic so every
/// [`crate::accel::nonlinear`] variant is measured through the same
/// harness with the same sampled rows.
pub fn softmax_stats_for(
    kernel: impl Fn(&[i32], &mut [i32]),
    rows: usize,
    width: usize,
    sigma: f64,
    seed: u64,
) -> SoftmaxErrorStats {
    let mut rng = Rng::new(seed);
    let mut stats = SoftmaxErrorStats {
        max_err: 0.0,
        mean_err: 0.0,
        max_sum_dev: 0.0,
    };
    let mut buf = vec![0i32; width];
    for _ in 0..rows {
        let xf: Vec<f64> = (0..width).map(|_| rng.normal() * sigma).collect();
        let xq: Vec<i32> = xf.iter().map(|&x| quantize(x as f32, DATA_FRAC)).collect();
        kernel(&xq, &mut buf);
        let m = xf.iter().cloned().fold(f64::MIN, f64::max);
        let e: Vec<f64> = xf.iter().map(|&v| (v - m).exp()).collect();
        let s: f64 = e.iter().sum();
        let mut rs = 0f64;
        for (q, ef) in buf.iter().zip(&e) {
            let p = *q as f64 / (1 << PROB_FRAC) as f64;
            let err = (p - ef / s).abs();
            stats.max_err = stats.max_err.max(err);
            stats.mean_err += err;
            rs += p;
        }
        stats.max_sum_dev = stats.max_sum_dev.max((rs - 1.0).abs());
    }
    stats.mean_err /= (rows * width) as f64;
    stats
}

/// Softmax error stats of the paper's baseline kernel:
/// (max abs prob error, max |row sum − 1|). Kept for callers predating
/// the design-generic [`softmax_stats_for`].
pub fn softmax_error_stats(rows: usize, width: usize, sigma: f64, seed: u64) -> (f64, f64) {
    let s = softmax_stats_for(softmax_row, rows, width, sigma, seed);
    (s.max_err, s.max_sum_dev)
}

/// GELU error stats for an arbitrary scalar kernel (Q7.8 → Q7.8) swept
/// over [lo, hi] — design-generic, same grid for every variant.
pub fn gelu_stats_for(
    kernel: impl Fn(i32) -> i32,
    lo: f64,
    hi: f64,
    step: f64,
) -> GeluErrorStats {
    let mut stats = GeluErrorStats {
        max_abs: 0.0,
        mean_abs: 0.0,
        max_rel: 0.0,
    };
    let mut n = 0usize;
    let mut x = lo;
    while x <= hi {
        let q = quantize(x as f32, DATA_FRAC);
        let got = kernel(q) as f64 / 256.0;
        let want = gelu_exact_f64(x);
        let err = (got - want).abs();
        stats.max_abs = stats.max_abs.max(err);
        stats.mean_abs += err;
        if want.abs() >= 0.25 {
            stats.max_rel = stats.max_rel.max(err / want.abs());
        }
        n += 1;
        x += step;
    }
    stats.mean_abs /= n.max(1) as f64;
    stats
}

/// GELU error stats of the baseline kernel over [lo, hi]:
/// (max abs error, max rel error vs |y|≥0.25).
pub fn gelu_error_stats(lo: f64, hi: f64, step: f64, corrected: bool) -> (f64, f64) {
    let s = gelu_stats_for(|q| gelu_fixed(q, corrected), lo, hi, step);
    (s.max_abs, s.max_rel)
}

/// Generic PWL-segment sweep: max relative error of an n-segment
/// endpoint-interpolated 2^f over [0,1) — justifies the paper's 8
/// segments (3 index bits).
pub fn pwl_exp2_error(segments: usize, samples: usize) -> f64 {
    let mut max_rel = 0f64;
    for i in 0..samples {
        let f = i as f64 / samples as f64;
        let s = ((f * segments as f64) as usize).min(segments - 1);
        let f0 = s as f64 / segments as f64;
        let f1 = (s + 1) as f64 / segments as f64;
        let y0 = 2f64.powf(f0);
        let y1 = 2f64.powf(f1);
        let approx = y0 + (y1 - y0) * (f - f0) / (f1 - f0);
        let want = 2f64.powf(f);
        max_rel = max_rel.max((approx - want).abs() / want);
    }
    max_rel
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eu_error_within_pwl_plus_quant_floor() {
        assert!(exp2_max_rel_error(-6.0, 6.0, 4001) < 8e-3);
        assert!(exp2_max_rel_error(0.0, 0.999, 2001) < 1.5e-3);
    }

    #[test]
    fn log2_error_at_eq12_bound() {
        let e = log2_max_abs_error(500);
        assert!(e < 0.0875, "e={e}");
        assert!(e > 0.07, "sweep should reach near the bound, e={e}");
    }

    #[test]
    fn softmax_errors_small_across_scales() {
        for sigma in [1.0, 3.0, 6.0] {
            let (max_err, sum_dev) = softmax_error_stats(100, 49, sigma, 9);
            assert!(max_err < 0.06, "sigma={sigma}: {max_err}");
            assert!(sum_dev < 0.16, "sigma={sigma}: {sum_dev}");
        }
    }

    #[test]
    fn generic_stats_match_legacy_wrappers() {
        let s = softmax_stats_for(softmax_row, 50, 49, 3.0, 9);
        let (mx, sd) = softmax_error_stats(50, 49, 3.0, 9);
        assert_eq!(s.max_err, mx);
        assert_eq!(s.max_sum_dev, sd);
        assert!(s.mean_err > 0.0 && s.mean_err < s.max_err);
        let g = gelu_stats_for(|q| gelu_fixed(q, false), -4.0, 4.0, 0.01);
        let (ma, mr) = gelu_error_stats(-4.0, 4.0, 0.01, false);
        assert_eq!(g.max_abs, ma);
        assert_eq!(g.max_rel, mr);
        assert!(g.mean_abs > 0.0 && g.mean_abs < g.max_abs);
    }

    #[test]
    fn gelu_corrected_constant_helps_midrange() {
        let (abs_p, _) = gelu_error_stats(1.0, 2.5, 0.01, false);
        let (abs_c, _) = gelu_error_stats(1.0, 2.5, 0.01, true);
        assert!(abs_c <= abs_p + 1e-9, "paper {abs_p} corrected {abs_c}");
    }

    #[test]
    fn pwl_error_quarters_per_doubling() {
        // PWL error ~ 1/segments²: each doubling cuts it ~4×
        let e4 = pwl_exp2_error(4, 4000);
        let e8 = pwl_exp2_error(8, 4000);
        let e16 = pwl_exp2_error(16, 4000);
        assert!(e4 / e8 > 3.0 && e4 / e8 < 5.0, "{}", e4 / e8);
        assert!(e8 / e16 > 3.0 && e8 / e16 < 5.0, "{}", e8 / e16);
        // the paper's 8 segments: ~9.4e-4 max rel — of the same order as
        // the Q10 exponent-input quantisation floor (ln2·2⁻¹⁰ ≈ 6.8e-4),
        // i.e. more segments would be wasted precision
        assert!(e8 < 1.2e-3, "{e8}");
        assert!(e8 > 8e-4, "{e8}");
    }
}
