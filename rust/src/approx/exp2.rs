//! EU (Exponential Unit): `2^v` via piecewise-linear LUT + barrel shifter.
//!
//! Paper Eq. 10: `2^v = 2^frac(v) << int(v)`. `frac(v) ∈ [0,1)` is
//! evaluated with an 8-segment piecewise-linear LUT indexed by the top
//! three fractional bits ("the 9th, 8th, and 7th bits of frac(x_i)",
//! §IV.C.3); the integer part becomes a shift. Mirrors
//! `fixedpoint.exp2_fixed` bit-for-bit.

use crate::fixed::{EXP_FRAC, OUT_FRAC};

/// Shift clamp: keeps `2^v` representable in i32 with adder-tree headroom.
pub const SHIFT_MIN: i32 = -30;
pub const SHIFT_MAX: i32 = 13;

/// Slope LUT in Q2.14, endpoint-interpolated on segment s: [s/8, (s+1)/8).
/// Generated exactly like `fixedpoint._pwl_exp2_tables`:
/// `K[s] = round((2^((s+1)/8) - 2^(s/8)) * 8 * 2^14)`.
pub const K_LUT: [i32; 8] = [
    k_entry(0), k_entry(1), k_entry(2), k_entry(3),
    k_entry(4), k_entry(5), k_entry(6), k_entry(7),
];

/// Intercept LUT in Q2.14: `B[s] = round((2^(s/8) - K[s]_f * s/8) * 2^14)`.
pub const B_LUT: [i32; 8] = [
    b_entry(0), b_entry(1), b_entry(2), b_entry(3),
    b_entry(4), b_entry(5), b_entry(6), b_entry(7),
];

// const-fn generation is not possible with f64::powf; tables are literal
// values verified against the python generator in tests below.
const fn k_entry(s: usize) -> i32 {
    // round((2^((s+1)/8) - 2^(s/8)) * 8 * 2^14) for s = 0..7
    [11863, 12937, 14108, 15384, 16777, 18295, 19951, 21757][s]
}

const fn b_entry(s: usize) -> i32 {
    // round((2^(s/8) - k*(s/8)) * 2^14) for s = 0..7
    [16384, 16250, 15957, 15478, 14782, 13833, 12591, 11011][s]
}

/// `2^v` for `v` in Q*.EXP_FRAC, producing Q*.out_frac.
///
/// Underflow flushes toward zero; overflow saturates via the shift clamp —
/// exactly the hardware barrel-shifter behaviour.
#[inline]
pub fn exp2_fixed(v: i32, out_frac: u32) -> i32 {
    let int_part = v >> EXP_FRAC; // arithmetic floor
    let frac = v - (int_part << EXP_FRAC); // in [0, 2^10)
    let seg = (frac >> (EXP_FRAC - 3)) as usize; // top 3 fractional bits
    // K(Q2.14) * frac(Q0.10) >> 10 + B(Q2.14) -> 2^frac in Q2.14, [1,2)
    let p = ((K_LUT[seg] * frac) >> EXP_FRAC) + B_LUT[seg];
    let shift = (int_part + out_frac as i32 - OUT_FRAC as i32)
        .clamp(SHIFT_MIN, SHIFT_MAX);
    if shift >= 0 {
        p << shift
    } else {
        p >> (-shift)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::{EXP_FRAC, OUT_FRAC};

    /// Regenerate the LUTs in float and compare — guards against drift
    /// from the python generator.
    #[test]
    fn luts_match_generator() {
        for s in 0..8 {
            let f0 = s as f64 / 8.0;
            let f1 = (s + 1) as f64 / 8.0;
            let y0 = 2f64.powf(f0);
            let y1 = 2f64.powf(f1);
            let k = (y1 - y0) / (f1 - f0);
            let b = y0 - k * f0;
            assert_eq!(K_LUT[s], (k * (1 << OUT_FRAC) as f64).round() as i32);
            assert_eq!(B_LUT[s], (b * (1 << OUT_FRAC) as f64).round() as i32);
        }
    }

    #[test]
    fn integer_powers_exact() {
        for k in -8i32..=8 {
            let got = exp2_fixed(k << EXP_FRAC, OUT_FRAC);
            let want = 2f64.powi(k) * (1 << OUT_FRAC) as f64;
            assert!((got as f64 - want).abs() <= 1.0, "k={k} got={got}");
        }
    }

    #[test]
    fn pwl_relative_error_bound() {
        let mut max_rel: f64 = 0.0;
        for i in -6144..6144 {
            // v in Q*.10 covering [-6, 6)
            let got = exp2_fixed(i, OUT_FRAC) as f64 / (1 << OUT_FRAC) as f64;
            let want = 2f64.powf(i as f64 / 1024.0);
            max_rel = max_rel.max((got - want).abs() / want);
        }
        assert!(max_rel < 8e-3, "max_rel={max_rel}");
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut prev = i32::MIN;
        for i in (-8192..8192).step_by(3) {
            let y = exp2_fixed(i, OUT_FRAC);
            assert!(y >= prev, "at v={i}");
            prev = y;
        }
    }

    #[test]
    fn known_python_golden_values() {
        // from the verified python run: 2^0.5 -> 1.414185 in Q14 etc.
        let q = |x: f64| (x * 1024.0).round() as i32;
        assert_eq!(exp2_fixed(q(0.0), OUT_FRAC), 16384);
        assert_eq!(
            exp2_fixed(q(0.5), OUT_FRAC) as f64 / 16384.0,
            1.4141845703125
        );
        assert_eq!(
            exp2_fixed(q(-0.5), OUT_FRAC) as f64 / 16384.0,
            0.70709228515625
        );
        assert_eq!(exp2_fixed(q(5.0), OUT_FRAC), 32 * 16384);
    }

    #[test]
    fn underflow_and_overflow_clamped() {
        assert!(exp2_fixed(-40 << EXP_FRAC, OUT_FRAC) <= 1);
        assert_eq!(
            exp2_fixed(40 << EXP_FRAC, OUT_FRAC),
            exp2_fixed(SHIFT_MAX << EXP_FRAC, OUT_FRAC)
        );
    }

    #[test]
    fn output_frac_rescaling() {
        // same v at different out_frac scales by powers of two (within ulp)
        let v = 1536; // 1.5 in Q10
        let a = exp2_fixed(v, OUT_FRAC) as f64 / (1 << OUT_FRAC) as f64;
        let b = exp2_fixed(v, PROB_FRAC_TEST) as f64 / (1 << 15) as f64;
        assert!((a - b).abs() < 1e-3);
    }

    const PROB_FRAC_TEST: u32 = 15;
}
