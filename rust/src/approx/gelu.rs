//! GCU functional model: the paper's four-stage hardware GELU
//! (Fig. 10, Eqs. 8–9), bit-identical to `fixedpoint.gelu_fixed` and to
//! the AOT'd Pallas GCU kernel.
//!
//! Stage 1  poly  s = −2log₂e√(2/π) · (x + 0.044715·x³)   (shift-add)
//! Stage 2  EU    p = 2^s                                  (Q2.14)
//! Stage 3  DU    e = log₂a(|x|) − log₂a(1 + p)
//! Stage 4  EU    |g| = 2^e;  g = sign(x)·|g|

use super::division::div_exponent;
use super::exp2::exp2_fixed;
use super::log2e::{mul_gelu_cubic, mul_gelu_cubic_corrected, mul_neg2log2e_sqrt2pi};
use crate::fixed::{sat16, DATA_FRAC, EXP_FRAC, OUT_FRAC};

/// Polynomial input clamp (Q7.8): |x| ≤ 8.0. Outside, GELU is already the
/// identity / zero; the clamp bounds x³ to the i32 datapath (hardware
/// saturates identically). Mirrors `fixedpoint.GELU_X_CLAMP`.
pub const X_CLAMP: i32 = 8 << DATA_FRAC;

/// Hardware GELU over one Q7.8 value.
#[inline]
pub fn gelu_fixed(x: i32, corrected_cubic: bool) -> i32 {
    let xc = x.clamp(-X_CLAMP, X_CLAMP);
    let x2 = (xc * xc) >> DATA_FRAC; // Q7.8, ≥ 0
    let x3 = (x2 * xc) >> DATA_FRAC; // Q*.8
    let cub = if corrected_cubic {
        mul_gelu_cubic_corrected(x3)
    } else {
        mul_gelu_cubic(x3)
    };
    let u = xc + cub; // Q*.8
    let s = mul_neg2log2e_sqrt2pi(u); // Q*.8
    let s10 = s << (EXP_FRAC - DATA_FRAC); // Q*.10
    let p = exp2_fixed(s10, OUT_FRAC); // 2^s, Q2.14 (shift-clamped)
    let den = p + (1 << OUT_FRAC); // 1 + 2^s
    let ax = x.abs();
    if ax == 0 {
        return 0;
    }
    let e = div_exponent(ax.max(1), DATA_FRAC, den, OUT_FRAC);
    let mag = exp2_fixed(e, DATA_FRAC); // Q7.8
    sat16(x.signum() * mag)
}

/// GCU over a slice (row-major tensor of FFN activations).
pub fn gelu_slice(xs: &[i32], corrected_cubic: bool) -> Vec<i32> {
    xs.iter().map(|&x| gelu_fixed(x, corrected_cubic)).collect()
}

/// Float reference (exact tanh GELU) for error measurement in benches.
pub fn gelu_exact_f64(x: f64) -> f64 {
    let c = (2.0 / std::f64::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::quantize;

    fn q8(x: f64) -> i32 {
        quantize(x as f32, DATA_FRAC)
    }

    #[test]
    fn zero_is_zero() {
        assert_eq!(gelu_fixed(0, false), 0);
    }

    #[test]
    fn small_x_accuracy() {
        for i in -150..=150 {
            let x = i as f64 / 100.0;
            let got = gelu_fixed(q8(x), false) as f64 / 256.0;
            let want = gelu_exact_f64(x);
            assert!((got - want).abs() < 0.06, "x={x} got={got} want={want}");
        }
    }

    #[test]
    fn large_positive_lod_ripple_bound() {
        // for x >> 0, gelu(x) → x; Eq. 12 ripple bounds error to ~6% rel
        for i in 20..=75 {
            let x = i as f64 / 10.0;
            let got = gelu_fixed(q8(x), false) as f64 / 256.0;
            assert!((got - x).abs() / x < 0.07, "x={x} got={got}");
        }
    }

    #[test]
    fn negative_tail_flushes_to_zero() {
        for i in 40..=80 {
            let x = -(i as f64) / 10.0;
            let got = gelu_fixed(q8(x), false) as f64 / 256.0;
            assert!(got.abs() < 0.02, "x={x} got={got}");
        }
    }

    #[test]
    fn beyond_clamp_behaves_like_clamp_region() {
        // |x| > 8 enters through the same saturated polynomial; outputs
        // keep the identity/zero asymptotes (up to the LOD ripple)
        let g = gelu_fixed(q8(12.0), false) as f64 / 256.0;
        assert!(g > 10.0, "{g}"); // ~ x within ripple
        let g = gelu_fixed(q8(-12.0), false) as f64 / 256.0;
        assert!(g.abs() < 0.02);
    }

    #[test]
    fn reflection_identity_approx() {
        // gelu(x) − gelu(−x) = x·Φ(x) + x·Φ(−x) = x exactly;
        // the approximation must hold it to within the LOD ripple
        for i in 1..=40 {
            let x = i as f64 / 10.0;
            let d = (gelu_fixed(q8(x), false) - gelu_fixed(q8(-x), false)) as f64 / 256.0;
            assert!((d - x).abs() < 0.07 * x + 0.12, "x={x} diff={d}");
        }
    }

    #[test]
    fn corrected_constant_changes_midrange_only() {
        // the 4.8%-high cubic constant must shift at least some outputs
        // in the poly-dominant zone |x| ∈ [1, 3]...
        let diffs = (10..=30)
            .filter(|&i| {
                let x = i as f64 / 10.0;
                gelu_fixed(q8(x), false) != gelu_fixed(q8(x), true)
                    || gelu_fixed(q8(-x), false) != gelu_fixed(q8(-x), true)
            })
            .count();
        assert!(diffs > 0, "corrected constant changed nothing");
        // ...and none near zero where x³ vanishes
        assert_eq!(gelu_fixed(q8(0.05), false), gelu_fixed(q8(0.05), true));
    }

    #[test]
    fn slice_matches_scalar() {
        let xs: Vec<i32> = (-10..10).map(|i| i * 100).collect();
        let ys = gelu_slice(&xs, false);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(*y, gelu_fixed(*x, false));
        }
    }
}
