//! Shift-add constant multipliers (paper §III.B / Eq. 9).
//!
//! The paper replaces constant multiplications with shift-add chains:
//!
//! * `log₂e ≈ 1.0111b = 1 + 2⁻¹ − 2⁻⁴ = 1.4375` (true value 1.442695,
//!   −0.36% — the softmax numerator/denominator share it, so the ratio
//!   error largely cancels)
//! * `−2·log₂e·√(2/π) ≈ −10.0101b = −(2 + 2⁻² + 2⁻⁴) = −2.3125`
//!   (true value −2.302009, +0.46%)
//! * `0.044715 ≈ 0.000011b = 2⁻⁵ + 2⁻⁶ = 0.046875` (+4.83% — the paper's
//!   coarsest constant; [`mul_gelu_cubic_corrected`] is our 12-bit
//!   ablation, DESIGN.md §6)
//!
//! Bit-identical to `fixedpoint.mul_log2e` & friends.

/// `x × log₂e` via `x + (x>>1) − (x>>4)`. Any Q format; result same format.
#[inline(always)]
pub fn mul_log2e(x: i32) -> i32 {
    x + (x >> 1) - (x >> 4)
}

/// `u × (−2·log₂e·√(2/π))` via `−(2u + (u>>2) + (u>>4))`.
#[inline(always)]
pub fn mul_neg2log2e_sqrt2pi(u: i32) -> i32 {
    -((u << 1) + (u >> 2) + (u >> 4))
}

/// `x³ × 0.044715` with the paper's 6-bit constant: `(x³>>5) + (x³>>6)`.
#[inline(always)]
pub fn mul_gelu_cubic(x3: i32) -> i32 {
    (x3 >> 5) + (x3 >> 6)
}

/// 12-bit corrected cubic constant: `round(0.044715 · 2¹²) = 183`,
/// decomposed as `128+32+16+4+2+1` shift-adds, then `>> 12`.
#[inline(always)]
pub fn mul_gelu_cubic_corrected(x3: i32) -> i32 {
    ((x3 << 7) + (x3 << 5) + (x3 << 4) + (x3 << 2) + (x3 << 1) + x3) >> 12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2e_constant_value() {
        assert_eq!(mul_log2e(1 << 12), ((1.4375f64) * 4096.0) as i32);
    }

    #[test]
    fn neg2log2e_constant_value() {
        assert_eq!(mul_neg2log2e_sqrt2pi(1 << 12), -(2.3125f64 * 4096.0) as i32);
    }

    #[test]
    fn cubic_constant_value() {
        assert_eq!(mul_gelu_cubic(1 << 12), (0.046875f64 * 4096.0) as i32);
    }

    #[test]
    fn corrected_cubic_is_closer() {
        let x3 = 1 << 14;
        let paper = mul_gelu_cubic(x3) as f64 / 16384.0;
        let corr = mul_gelu_cubic_corrected(x3) as f64 / 16384.0;
        assert!((corr - 0.044715).abs() < (paper - 0.044715).abs());
        assert!((corr - 0.044715).abs() < 1e-4);
    }

    #[test]
    fn linear_in_argument() {
        for x in [-5000i32, -7, 0, 7, 12345] {
            assert_eq!(mul_log2e(2 * 1024 * x / 1024), mul_log2e(2 * x) / 1 * 1);
            // scaling sanity (shift-add is not exactly linear under floor
            // division for negatives; just check sign/monotone behaviour)
            assert_eq!(mul_log2e(x).signum(), x.signum());
        }
    }

    #[test]
    fn negative_values_floor_semantics() {
        // jnp: -3 >> 1 == -2 (floor). rust i32 >> matches.
        assert_eq!(mul_log2e(-16), -16 + (-8) - (-1));
        assert_eq!(mul_gelu_cubic(-64), (-2) + (-1));
    }
}
