//! The paper's approximate-computation architectures (§III.B, Eqs. 5–12)
//! as bit-accurate functional models.
//!
//! Each submodule mirrors one hardware unit:
//!
//! * [`exp2`]     — the EU: 8-segment piecewise-linear `2^frac` + barrel
//!   shifter (Eq. 10, Fig. 8)
//! * [`log2e`]    — the shift-add constant multipliers (`×log₂e`,
//!   `×(−2log₂e√(2/π))`, `×0.044715`)
//! * [`division`] — the LOD + DU log-domain division (Eqs. 11–12, Fig. 9)
//! * [`softmax`]  — the full SCU dataflow (Eq. 6, Fig. 6)
//! * [`gelu`]     — the full GCU dataflow (Eqs. 8–9, Fig. 10)
//! * [`peano`]    — the PEANO-style division/root-free normalisation
//!   (shift-add reciprocal) used by the alternative SCU/GCU design in
//!   [`crate::accel::nonlinear`]
//!
//! These are the *numerics*; the cycle-level pipeline models live in
//! [`crate::accel`]. Bit-equivalence with `python/compile/fixedpoint.py`
//! is asserted by unit tests here (golden vectors) and end-to-end by
//! `rust/tests/cross_check.rs` through the AOT'd Pallas kernels.

pub mod division;
pub mod error;
pub mod exp2;
pub mod gelu;
pub mod log2e;
pub mod peano;
pub mod softmax;
