//! PEANO-style division/root-free normalisation (after PEANO-ViT,
//! arXiv 2406.14854): replaces the paper's log-domain DU (LOD + log₂
//! approximation + EU, Eqs. 11–12) with a **shift-add reciprocal** —
//! no divider, no barrel-shifted exponent reconstruction, no DSP-hungry
//! second EU pass.
//!
//! The core identity: for `den > 0` with bit length k₁ (so
//! `den = 2^k₁·(1 − x)` with `x ∈ [0, ½)`),
//!
//! ```text
//!   1/den = (1/2^k₁) · (1 + x + x² + x³ + x⁴ + …)
//! ```
//!
//! [`recip_shift_add`] evaluates the first five terms by Horner's rule —
//! three fused iterations of `h ← one + ((t·h) >> k₁)` over the scaled
//! remainder `t = 2^k₁ − den` — producing `h ≈ 2^(2k₁)/den`. Truncating
//! after x⁴ bounds the *relative* error by `x⁵ ≤ 2⁻⁵ = 3.125 %`
//! (worst case exactly at `x = ½`; zero when `den` is a power of two).
//! Each iteration is one multiply + shift + add: the whole reciprocal is
//! 3 multiplies, against the baseline's LOD→log₂→subtract→EU chain.
//!
//! The softmax/GELU front ends (FMU max, shift-add ×log₂e, PWL 2^v) are
//! the paper's circuits unchanged — only the normalisation differs, so
//! the extra error this design introduces is exactly the reciprocal
//! truncation, measured end-to-end by `approx::error` and pinned by
//! `rust/tests/nonlinear_designs.rs`. Mirrored in
//! `python/compile/fixedpoint.py` (`recip_shift_add`,
//! `softmax_fixed_peano`, `gelu_fixed_peano`).

use super::exp2::exp2_fixed;
use super::gelu::X_CLAMP;
use super::log2e::{mul_gelu_cubic, mul_log2e, mul_neg2log2e_sqrt2pi};
use super::softmax::fmu_max;
use crate::fixed::{sat16, DATA_FRAC, EXP_FRAC, I16_MAX, OUT_FRAC, PROB_FRAC};

/// Shift-add reciprocal: `(h, k1)` with `h ≈ 2^(2k1) / den` where `k1`
/// is the bit length of `den`. Four-term Horner expansion of the
/// geometric series (see module docs); `h` fits well inside i64
/// (`h < 2^(k1+1)`), callers shift products down by `2k1 − out_frac`.
#[inline]
pub fn recip_shift_add(den: i32) -> (i64, u32) {
    debug_assert!(den > 0, "reciprocal of non-positive {den}");
    let k1 = 32 - den.leading_zeros(); // bit length: 2^(k1-1) <= den < 2^k1
    let one = 1i64 << k1;
    let t = one - den as i64; // 0 <= t < 2^(k1-1)
    let mut h = one + t; // 1 + x
    for _ in 0..3 {
        h = one + ((t * h) >> k1); // Horner: 1 + x·(previous)
    }
    (h, k1)
}

/// PEANO softmax over one row of Q7.8 logits → Q0.15 probabilities.
/// Stages 1–2 are the paper's SCU (max, shift-add ×log₂e, PWL 2^v,
/// 1-ulp floor); the log-domain DU + second EU pass is replaced by one
/// shared shift-add reciprocal of the row sum.
pub fn softmax_row_peano(row: &[i32], out: &mut [i32]) {
    debug_assert_eq!(row.len(), out.len());
    let xmax = fmu_max(row);
    let mut sum: i32 = 0;
    for (i, &x) in row.iter().enumerate() {
        let d = x - xmax; // <= 0, Q7.8
        let v = mul_log2e(d) << (EXP_FRAC - DATA_FRAC); // Q*.10
        let p = exp2_fixed(v, OUT_FRAC).max(1); // Q2.14
        out[i] = p;
        sum += p; // n <= 64 lanes of Q2.14 fits i32
    }
    // sum >= 2^OUT_FRAC (the max lane contributes exactly 1.0), so
    // k1 >= 15 and the shift is non-negative: out = p·h >> (2k1 − 15)
    let (h, k1) = recip_shift_add(sum);
    let sh = 2 * k1 - PROB_FRAC as u32;
    for o in out.iter_mut() {
        *o = ((*o as i64 * h) >> sh).clamp(0, I16_MAX as i64) as i32;
    }
}

/// PEANO softmax over a row-major (rows × width) matrix.
pub fn softmax_rows_peano(x: &[i32], width: usize) -> Vec<i32> {
    assert!(width > 0 && x.len() % width == 0);
    let mut out = vec![0i32; x.len()];
    for (rin, rout) in x.chunks_exact(width).zip(out.chunks_exact_mut(width)) {
        softmax_row_peano(rin, rout);
    }
    out
}

/// PEANO GELU over one Q7.8 value: the paper's polynomial + PWL-2^s
/// front end, with `|x| / (1 + 2^s)` computed by the shift-add
/// reciprocal instead of the log-domain DU.
#[inline]
pub fn gelu_fixed_peano(x: i32) -> i32 {
    let xc = x.clamp(-X_CLAMP, X_CLAMP);
    let x2 = (xc * xc) >> DATA_FRAC;
    let x3 = (x2 * xc) >> DATA_FRAC;
    let u = xc + mul_gelu_cubic(x3); // Q*.8
    let s = mul_neg2log2e_sqrt2pi(u); // Q*.8
    let s10 = s << (EXP_FRAC - DATA_FRAC); // Q*.10
    let p = exp2_fixed(s10, OUT_FRAC); // 2^s, Q2.14
    let den = p + (1 << OUT_FRAC); // 1 + 2^s in [2^14, 2^15]
    let ax = x.abs();
    if ax == 0 {
        return 0;
    }
    // |g| in Q7.8 = ax·2^14/den = ax·h >> (2k1 − 14); k1 = 15 always
    let (h, k1) = recip_shift_add(den);
    let mag = ((ax as i64 * h) >> (2 * k1 - OUT_FRAC as u32)) as i32;
    sat16(x.signum() * mag)
}

/// PEANO GCU over a slice.
pub fn gelu_slice_peano(xs: &[i32]) -> Vec<i32> {
    xs.iter().map(|&x| gelu_fixed_peano(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::approx::gelu::gelu_exact_f64;
    use crate::fixed::quantize;

    #[test]
    fn reciprocal_power_of_two_is_the_worst_case() {
        // den = 2^k sits at the bottom of its bit-length bracket: x = 1/2
        // exactly, so the truncation hits its x^5 = 3.125 % bound — and
        // every intermediate is a power of two, so it hits it *exactly*
        // once k is large enough for the >> k1 shifts to be lossless
        for k in 3..30 {
            let den = 1i32 << k;
            let (h, k1) = recip_shift_add(den);
            assert_eq!(k1, k as u32 + 1);
            let want = (1i64 << (2 * k1)) / den as i64;
            let rel = (h - want).abs() as f64 / want as f64;
            assert!((rel - 0.03125).abs() < 1e-9, "den=2^{k}: h={h} want={want} rel={rel}");
        }
    }

    #[test]
    fn reciprocal_error_within_truncation_bound() {
        // relative error of h vs 2^(2k1)/den is bounded by x^5 <= 3.125 %
        // plus a few fixed-point truncation ulps (negligible over the
        // units' operating range: softmax sums and GELU denominators are
        // always >= 2^14)
        let mut worst = 0f64;
        let mut den = 1025i64;
        while den < (1 << 28) {
            let (h, k1) = recip_shift_add(den as i32);
            let want = 2f64.powi(2 * k1 as i32) / den as f64;
            let rel = (h as f64 - want).abs() / want;
            worst = worst.max(rel);
            assert!(rel < 0.033, "den={den}: rel={rel}");
            den = den * 7 / 4 + 1;
        }
        // the sweep must actually approach the x = 1/2 worst case
        assert!(worst > 0.02, "sweep too easy: worst={worst}");
    }

    #[test]
    fn softmax_row_sums_near_one() {
        let xs: Vec<i32> = (0..49)
            .map(|i| quantize(((i as f64 * 0.711).sin() * 4.0) as f32, DATA_FRAC))
            .collect();
        let mut out = vec![0; 49];
        softmax_row_peano(&xs, &mut out);
        let s: f64 = out.iter().map(|&v| v as f64 / 32768.0).sum();
        assert!(s > 0.9 && s < 1.1, "sum={s}");
    }

    #[test]
    fn softmax_shift_invariance() {
        let xs: Vec<i32> = [-1.0, 0.5, 2.0, -3.0, 1.25]
            .iter()
            .map(|&x| quantize(x, DATA_FRAC))
            .collect();
        let shifted: Vec<i32> = xs.iter().map(|x| x + (7 << DATA_FRAC)).collect();
        let (mut a, mut b) = (vec![0; 5], vec![0; 5]);
        softmax_row_peano(&xs, &mut a);
        softmax_row_peano(&shifted, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn softmax_one_hot_for_extreme_logit() {
        let mut xs = vec![quantize(-20.0, DATA_FRAC); 49];
        xs[7] = quantize(20.0, DATA_FRAC);
        let mut out = vec![0; 49];
        softmax_row_peano(&xs, &mut out);
        assert!(out[7] as f64 / 32768.0 > 0.95);
    }

    #[test]
    fn gelu_zero_and_signs() {
        assert_eq!(gelu_fixed_peano(0), 0);
        assert!(gelu_fixed_peano(quantize(2.0, DATA_FRAC)) > 0);
        // deep negative tail flushes to ~0
        let g = gelu_fixed_peano(quantize(-6.0, DATA_FRAC));
        assert!((g as f64 / 256.0).abs() < 0.02, "{g}");
    }

    #[test]
    fn gelu_positive_asymptote() {
        // for x >> 0, gelu(x) -> x; the reciprocal truncation (~3.1 %)
        // plus the PWL front end stays under the baseline's ~7 % ripple
        for i in 20..=75 {
            let x = i as f64 / 10.0;
            let got = gelu_fixed_peano(quantize(x as f32, DATA_FRAC)) as f64 / 256.0;
            assert!((got - x).abs() / x < 0.055, "x={x} got={got}");
        }
    }

    #[test]
    fn gelu_accuracy_beats_loose_bound() {
        for i in -400..=400 {
            let x = i as f64 / 100.0;
            let got = gelu_fixed_peano(quantize(x as f32, DATA_FRAC)) as f64 / 256.0;
            let want = gelu_exact_f64(x);
            assert!((got - want).abs() < 0.2, "x={x} got={got} want={want}");
        }
    }

    #[test]
    fn slice_matches_scalar() {
        let xs: Vec<i32> = (-10..10).map(|i| i * 100).collect();
        let ys = gelu_slice_peano(&xs);
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(*y, gelu_fixed_peano(*x));
        }
    }

    #[test]
    fn matrix_helper_matches_rowwise() {
        let xs: Vec<i32> = (0..98).map(|i| ((i * 41 % 97) - 48) * 8).collect();
        let m = softmax_rows_peano(&xs, 49);
        let mut row0 = vec![0; 49];
        softmax_row_peano(&xs[..49], &mut row0);
        assert_eq!(&m[..49], &row0[..]);
    }
}
