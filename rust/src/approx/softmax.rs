//! SCU functional model: the paper's four-stage hardware softmax
//! (Fig. 6, Eq. 6), bit-identical to `fixedpoint.softmax_fixed` and to the
//! AOT'd Pallas SCU kernel.
//!
//! Stage 1  FMU        row max (grouped compare tree, Fig. 7)
//! Stage 2  EU         d = x − max; v = d·log₂e (shift-add); p = 2^v
//! Stage 3  AdderTree  S = Σp;  DU: e = log₂a(p) − log₂a(S)
//! Stage 4  EU         out = 2^e in Q0.15

use super::division::div_exponent;
use super::exp2::exp2_fixed;
use super::log2e::mul_log2e;
use crate::fixed::{DATA_FRAC, EXP_FRAC, I16_MAX, OUT_FRAC, PROB_FRAC};

/// FMU: maximum of a row. The hardware splits n into power-of-two groups
/// (Fig. 7) purely for cycle parallelism; the result is an exact max.
#[inline]
pub fn fmu_max(row: &[i32]) -> i32 {
    *row.iter().max().expect("FMU on empty row")
}

/// Full SCU over one row of Q7.8 logits → Q0.15 probabilities.
pub fn softmax_row(row: &[i32], out: &mut [i32]) {
    debug_assert_eq!(row.len(), out.len());
    let xmax = fmu_max(row);
    // Stage 2: p_i = 2^(log2e * (x_i - xmax)) in Q2.14, floored at 1 ulp
    let mut sum: i32 = 0;
    for (i, &x) in row.iter().enumerate() {
        let d = x - xmax; // <= 0, Q7.8
        let v = mul_log2e(d) << (EXP_FRAC - DATA_FRAC); // Q*.10
        let p = exp2_fixed(v, OUT_FRAC).max(1);
        out[i] = p; // stash p in out (Stage 3 reads it back)
        sum += p; // adder tree: n <= 64 lanes of Q2.14 fits i32
    }
    // Stage 3+4: e = log2a(p) - log2a(S); out = 2^e in Q0.15
    for o in out.iter_mut() {
        let e = div_exponent(*o, OUT_FRAC, sum, OUT_FRAC);
        *o = exp2_fixed(e, PROB_FRAC).clamp(0, I16_MAX);
    }
}

/// SCU over a row-major matrix (rows × width), e.g. a 49×49 score matrix.
pub fn softmax_rows(x: &[i32], width: usize) -> Vec<i32> {
    assert!(width > 0 && x.len() % width == 0);
    let mut out = vec![0i32; x.len()];
    for (rin, rout) in x.chunks_exact(width).zip(out.chunks_exact_mut(width)) {
        softmax_row(rin, rout);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::quantize;

    fn q8(xs: &[f64]) -> Vec<i32> {
        xs.iter().map(|&x| quantize(x as f32, DATA_FRAC)).collect()
    }

    fn dq15(xs: &[i32]) -> Vec<f64> {
        xs.iter().map(|&x| x as f64 / (1 << PROB_FRAC) as f64).collect()
    }

    fn exact(xs: &[f64]) -> Vec<f64> {
        let m = xs.iter().cloned().fold(f64::MIN, f64::max);
        let e: Vec<f64> = xs.iter().map(|&x| (x - m).exp()).collect();
        let s: f64 = e.iter().sum();
        e.iter().map(|&v| v / s).collect()
    }

    #[test]
    fn close_to_exact_softmax() {
        let xs: Vec<f64> = (0..49).map(|i| ((i * 37 % 23) as f64 - 11.0) / 4.0).collect();
        let mut out = vec![0; 49];
        softmax_row(&q8(&xs), &mut out);
        let got = dq15(&out);
        let want = exact(&xs);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 0.05, "{g} vs {w}");
        }
    }

    #[test]
    fn row_sums_near_one() {
        let xs: Vec<f64> = (0..49).map(|i| (i as f64 * 0.711).sin() * 4.0).collect();
        let mut out = vec![0; 49];
        softmax_row(&q8(&xs), &mut out);
        let s: f64 = dq15(&out).iter().sum();
        assert!(s > 0.85 && s < 1.15, "sum={s}");
    }

    #[test]
    fn shift_invariance() {
        let xs = q8(&[-1.0, 0.5, 2.0, -3.0, 1.25]);
        let shifted: Vec<i32> = xs.iter().map(|x| x + (7 << DATA_FRAC)).collect();
        let mut a = vec![0; 5];
        let mut b = vec![0; 5];
        softmax_row(&xs, &mut a);
        softmax_row(&shifted, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn argmax_preserved() {
        let xs: Vec<f64> = (0..49).map(|i| ((i * 13 % 17) as f64) / 3.0).collect();
        let mut out = vec![0; 49];
        softmax_row(&q8(&xs), &mut out);
        let am_in = xs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let am_out = out.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0;
        // ties in fixed point may pick an equal-valued earlier index
        assert_eq!(out[am_in], out[am_out]);
    }

    #[test]
    fn one_hot_for_extreme_logit() {
        let mut xs = vec![-20.0; 49];
        xs[7] = 20.0;
        let mut out = vec![0; 49];
        softmax_row(&q8(&xs), &mut out);
        let got = dq15(&out);
        assert!(got[7] > 0.95);
        for (i, g) in got.iter().enumerate() {
            if i != 7 {
                assert!(*g < 0.01);
            }
        }
    }

    #[test]
    fn matrix_helper_matches_rowwise() {
        let xs: Vec<i32> = (0..98).map(|i| ((i * 41 % 97) - 48) * 8).collect();
        let m = softmax_rows(&xs, 49);
        let mut row0 = vec![0; 49];
        softmax_row(&xs[..49], &mut row0);
        assert_eq!(&m[..49], &row0[..]);
    }
}
