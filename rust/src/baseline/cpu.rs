//! CPU baseline: AMD Ryzen 5700X (paper §V.E — PyTorch 2.0, WSL2).
//!
//! Peak fp32 throughput: 8 cores × 4.0 GHz × 16 FLOP/cycle (AVX2 FMA ×2
//! ports) = 512 GFLOP/s. PyTorch inference sustains a model-dependent
//! fraction; the factors below are calibrated so the modelled FPS matches
//! the paper's reported speedups exactly (FPGA-FPS ÷ speedup), making
//! this the paper's own measurement restated as a model — larger models
//! sustain higher efficiency (better GEMM blocking amortisation).

use crate::model::config::SwinVariant;
use crate::model::graph::WorkloadGraph;

use super::DevicePoint;

/// Datasheet-ish peak, fp32 FLOP/s.
pub const PEAK_FLOPS: f64 = 512e9;
/// Package power under inference load (paper: "approximately 120 W",
/// HWiNFO64 package power).
pub const POWER_W: f64 = 120.0;

/// Sustained efficiency fraction per variant (calibrated, see module doc).
pub fn efficiency(v: &SwinVariant) -> f64 {
    match v.name {
        "swin-t" => 0.478,
        "swin-s" => 0.527,
        "swin-b" => 0.630,
        // micro and others: small-kernel regime, poor amortisation
        _ => 0.25,
    }
}

/// Modelled FPS: peak × efficiency / (2 FLOP per MAC × MACs).
pub fn fps(v: &SwinVariant) -> f64 {
    let macs = WorkloadGraph::build(v).total_macs() as f64;
    PEAK_FLOPS * efficiency(v) / (2.0 * macs)
}

pub fn point(v: &SwinVariant) -> DevicePoint {
    DevicePoint {
        fps: fps(v),
        power_w: POWER_W,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, SMALL, TINY};

    #[test]
    fn calibration_reproduces_paper_speedup_anchors() {
        // paper: accelerator is 1.76/1.66/1.25× the CPU at 48.1/25.0/13.1
        // FPS ⇒ CPU ≈ 27.3 / 15.1 / 10.5 FPS
        assert!((fps(&TINY) - 27.3).abs() < 1.5, "{}", fps(&TINY));
        assert!((fps(&SMALL) - 15.1).abs() < 1.0, "{}", fps(&SMALL));
        assert!((fps(&BASE) - 10.5).abs() < 0.8, "{}", fps(&BASE));
    }

    #[test]
    fn ordering_t_faster_than_b() {
        assert!(fps(&TINY) > fps(&SMALL));
        assert!(fps(&SMALL) > fps(&BASE));
    }

    #[test]
    fn efficiency_fractions_plausible() {
        for v in [&TINY, &SMALL, &BASE] {
            let e = efficiency(v);
            assert!(e > 0.1 && e < 0.8, "{}: {e}", v.name);
        }
    }
}
