//! GPU baseline: Nvidia RTX 2080 Ti (paper §V.E).
//!
//! Peak fp32 13.45 TFLOP/s (4352 CUDA cores × 1545 MHz boost × 2).
//! Efficiency factors calibrated from the paper's reported speedups
//! (FPGA-FPS ÷ speedup): windowed attention at batch 1 sustains a small
//! fraction of peak, growing with model width.

use crate::model::config::SwinVariant;
use crate::model::graph::WorkloadGraph;

use super::DevicePoint;

pub const PEAK_FLOPS: f64 = 13.45e12;
/// Board power under inference load (paper: "approximately 240 W").
pub const POWER_W: f64 = 240.0;

pub fn efficiency(v: &SwinVariant) -> f64 {
    match v.name {
        "swin-t" => 0.160,
        "swin-s" => 0.190,
        "swin-b" => 0.233,
        _ => 0.05,
    }
}

pub fn fps(v: &SwinVariant) -> f64 {
    let macs = WorkloadGraph::build(v).total_macs() as f64;
    PEAK_FLOPS * efficiency(v) / (2.0 * macs)
}

pub fn point(v: &SwinVariant) -> DevicePoint {
    DevicePoint {
        fps: fps(v),
        power_w: POWER_W,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, SMALL, TINY};

    #[test]
    fn calibration_reproduces_paper_anchor_fps() {
        // paper: accelerator reaches 0.20/0.17/0.12× of the GPU
        // ⇒ GPU ≈ 240 / 147 / 109 FPS
        assert!((fps(&TINY) - 240.0).abs() < 15.0, "{}", fps(&TINY));
        assert!((fps(&SMALL) - 147.0).abs() < 10.0, "{}", fps(&SMALL));
        assert!((fps(&BASE) - 109.0).abs() < 8.0, "{}", fps(&BASE));
    }

    #[test]
    fn gpu_beats_cpu_on_throughput() {
        for v in [&TINY, &SMALL, &BASE] {
            assert!(fps(v) > super::super::cpu::fps(v), "{}", v.name);
        }
    }
}
