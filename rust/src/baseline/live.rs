//! Live CPU measurement: run the AOT swin-micro artifact on this
//! machine's CPU through the real PJRT path and report FPS per batch
//! size. This exercises the full serving stack (artifact load → compile
//! → execute) with real compute, complementing the calibrated device
//! models of [`super::cpu`] / [`super::gpu`].

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Runtime, Tensor};
use crate::util::prng::Rng;

/// One batch-size measurement.
#[derive(Debug, Clone)]
pub struct LivePoint {
    pub batch: usize,
    pub mean_ms: f64,
    pub images_per_sec: f64,
}

/// Measure every float serving artifact with `iters` timed runs each.
pub fn measure(artifacts_dir: &Path, iters: usize) -> Result<Vec<LivePoint>> {
    let rt = Runtime::new(artifacts_dir)?;
    let mut rng = Rng::new(42);
    let mut out = Vec::new();
    for (batch, name) in rt.serving_artifacts() {
        let eng = rt.engine(&name)?;
        let n = eng.info.inputs[0].numel();
        let img: Vec<f32> = (0..n).map(|_| rng.range_f32(0.0, 1.0)).collect();
        // warmup
        for _ in 0..2 {
            eng.run(&[Tensor::F32(img.clone())])?;
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            eng.run(&[Tensor::F32(img.clone())])?;
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        out.push(LivePoint {
            batch,
            mean_ms,
            images_per_sec: batch as f64 * 1e3 / mean_ms,
        });
    }
    Ok(out)
}

/// Human-readable summary used by `swin-fpga report`.
pub fn measure_live_cpu(artifacts_dir: &Path, iters: usize) -> Result<String> {
    let points = measure(artifacts_dir, iters)?;
    let mut s = String::from(
        "Live CPU (this machine, PJRT, swin-micro float artifacts):\n",
    );
    for p in points {
        s.push_str(&format!(
            "  batch {:>2}: {:>8.2} ms/call  {:>8.1} images/s\n",
            p.batch, p.mean_ms, p.images_per_sec
        ));
    }
    Ok(s)
}
