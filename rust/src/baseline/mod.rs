//! Comparison baselines for Figs. 11–12 and Table V.
//!
//! Two kinds (DESIGN.md §5.4):
//!
//! * **device models** ([`cpu`], [`gpu`]) — the paper's AMD Ryzen 5700X
//!   and Nvidia RTX 2080 Ti, modelled from datasheet peaks with
//!   efficiency factors calibrated against the paper's own reported
//!   speedups (we do not own either device);
//! * **live measurement** ([`live`]) — this machine's CPU running the
//!   AOT swin-micro artifact through the real PJRT path, so the Fig. 11
//!   harness also exercises live code end-to-end.

pub mod cpu;
pub mod gpu;
pub mod live;

/// A baseline device's modelled operating point for one Swin variant.
#[derive(Debug, Clone, Copy)]
pub struct DevicePoint {
    pub fps: f64,
    pub power_w: f64,
}

impl DevicePoint {
    pub fn efficiency(&self) -> f64 {
        self.fps / self.power_w
    }
}
