//! 16-bit fixed-point substrate — the accelerator's numeric contract.
//!
//! Bit-identical mirror of `python/compile/fixedpoint.py`: every constant
//! and operation here must match the jnp int32 semantics exactly (floor
//! arithmetic shifts, wrap-on-overflow i32 adds/muls, explicit saturation
//! points). The cross-check is enforced end-to-end by
//! `rust/tests/cross_check.rs` against the AOT-compiled Pallas kernels.
//!
//! Formats (Q<int>.<frac>, signed two's complement):
//!
//! | tensor                    | format  | constant      |
//! |---------------------------|---------|---------------|
//! | activations               | Q7.8    | [`DATA_FRAC`] |
//! | weights (fused)           | Q3.12   | [`WEIGHT_FRAC`] |
//! | MMU accumulator           | i32     | wrap-around (DSP cascade is 48-bit on silicon; inputs are range-bounded so wrap never fires in practice — see DESIGN.md §5) |
//! | EU exponent domain        | Q*.10   | [`EXP_FRAC`]  |
//! | EU 2^frac output          | Q2.14   | [`OUT_FRAC`]  |
//! | softmax probabilities     | Q0.15   | [`PROB_FRAC`] |

/// Fractional bits of activations (Q7.8).
pub const DATA_FRAC: u32 = 8;
/// Fractional bits of fused weights (Q3.12).
pub const WEIGHT_FRAC: u32 = 12;
/// Fractional bits of the EU exponent domain.
pub const EXP_FRAC: u32 = 10;
/// Fractional bits of the EU PWL output (value in [1,2)).
pub const OUT_FRAC: u32 = 14;
/// Fractional bits of softmax probabilities (Q0.15).
pub const PROB_FRAC: u32 = 15;

pub const I16_MAX: i32 = i16::MAX as i32;
pub const I16_MIN: i32 = i16::MIN as i32;

/// Saturate an i32 lane into the int16 range (kept as i32, like the jnp
/// datapath keeps int32 lanes).
#[inline(always)]
pub fn sat16(x: i32) -> i32 {
    x.clamp(I16_MIN, I16_MAX)
}

/// MMU write-back requantisation: accumulator -> Q7.8.
/// Round-half-up then saturate; mirrors `fixedpoint.requantize_acc`.
#[inline(always)]
pub fn requantize_acc(acc: i32, rshift: u32) -> i32 {
    debug_assert!(rshift >= 1);
    sat16(acc.wrapping_add(1 << (rshift - 1)) >> rshift)
}

/// Quantise a float to fixed point with `frac` fractional bits,
/// round-to-nearest-even (matches `jnp.round`), saturating to i16 range.
#[inline]
pub fn quantize(x: f32, frac: u32) -> i32 {
    let scaled = (x as f64) * (1u64 << frac) as f64;
    // round-half-even to match numpy/jnp rounding
    let r = round_half_even(scaled);
    sat16(r as i32)
}

fn round_half_even(x: f64) -> i64 {
    let floor = x.floor();
    let diff = x - floor;
    if diff > 0.5 {
        floor as i64 + 1
    } else if diff < 0.5 {
        floor as i64
    } else {
        let f = floor as i64;
        if f % 2 == 0 {
            f
        } else {
            f + 1
        }
    }
}

/// Fixed point -> float.
#[inline]
pub fn dequantize(q: i32, frac: u32) -> f32 {
    q as f32 / (1u32 << frac) as f32
}

/// Quantise a float slice (activations, Q7.8 by default).
pub fn quantize_slice(xs: &[f32], frac: u32) -> Vec<i32> {
    xs.iter().map(|&x| quantize(x, frac)).collect()
}

/// Dequantise an i32 slice.
pub fn dequantize_slice(qs: &[i32], frac: u32) -> Vec<f32> {
    qs.iter().map(|&q| dequantize(q, frac)).collect()
}

/// Fixed-point mean over `n` lanes: `(sum * round(2^15/n) + 2^14) >> 15`,
/// mirroring the GAP reduction in `model.forward_fixed`.
#[inline]
pub fn fixed_mean(sum: i32, n: usize) -> i32 {
    let inv = ((1u64 << 15) as f64 / n as f64).round() as i32;
    sat16((sum.wrapping_mul(inv).wrapping_add(1 << 14)) >> 15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sat16_clamps_both_sides() {
        assert_eq!(sat16(40_000), I16_MAX);
        assert_eq!(sat16(-40_000), I16_MIN);
        assert_eq!(sat16(123), 123);
        assert_eq!(sat16(-123), -123);
    }

    #[test]
    fn requantize_rounds_half_up() {
        // mirrors python test_requantize: [128,127,-128,-129,384] >> 8
        // (-129 + 128) >> 8 == (-1) >> 8 == -1: arithmetic floor shift,
        // identical in numpy int32 and rust i32.
        assert_eq!(requantize_acc(128, 8), 1);
        assert_eq!(requantize_acc(127, 8), 0);
        assert_eq!(requantize_acc(-128, 8), 0);
        assert_eq!(requantize_acc(-129, 8), -1);
        assert_eq!(requantize_acc(384, 8), 2);
    }

    #[test]
    fn requantize_saturates() {
        assert_eq!(requantize_acc(1 << 30, 8), I16_MAX);
        assert_eq!(requantize_acc(-(1 << 30), 8), I16_MIN);
    }

    #[test]
    fn quantize_round_half_even() {
        assert_eq!(quantize(0.5 / 256.0, DATA_FRAC), 0); // 0.5 -> even 0
        assert_eq!(quantize(1.5 / 256.0, DATA_FRAC), 2); // 1.5 -> even 2
        assert_eq!(quantize(2.5 / 256.0, DATA_FRAC), 2); // 2.5 -> even 2
        assert_eq!(quantize(1.0, DATA_FRAC), 256);
        assert_eq!(quantize(-1.0, DATA_FRAC), -256);
    }

    #[test]
    fn quantize_saturates_to_i16() {
        assert_eq!(quantize(1000.0, DATA_FRAC), I16_MAX);
        assert_eq!(quantize(-1000.0, DATA_FRAC), I16_MIN);
    }

    #[test]
    fn dequantize_roundtrip() {
        for v in [-128.0f32, -1.5, 0.0, 0.25, 3.75, 127.0] {
            let q = quantize(v, DATA_FRAC);
            assert!((dequantize(q, DATA_FRAC) - v).abs() < 1.0 / 256.0);
        }
    }

    #[test]
    fn fixed_mean_of_constant_is_identity() {
        // mean of n copies of v: sum = n*v -> ~v
        for n in [49usize, 196] {
            let v = 300i32;
            let got = fixed_mean(v * n as i32, n);
            assert!((got - v).abs() <= 1, "n={n} got={got}");
        }
    }
}
