//! # swin-fpga — reproduction of "An Efficient FPGA-Based Accelerator for
//! # Swin Transformer" (Liu, Ren, Yin, 2023)
//!
//! The paper's artifact is an FPGA accelerator on a Xilinx XCZU19EG. This
//! crate reproduces the *system* as the Layer-3 Rust coordinator of a
//! three-layer Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * [`fixed`] / [`approx`] — the 16-bit fixed-point datapath and the
//!   paper's shift-add/LUT/LOD approximations (Eqs. 5–12), bit-identical
//!   to `python/compile/fixedpoint.py`.
//! * [`model`] — Swin variant configs, the per-layer workload graph, MAC
//!   counts (Eqs. 13–17), BN→linear fusion (Eqs. 2–4) and quantised
//!   weight loading.
//! * [`accel`] — the FPGA, simulated: MMU / SCU / GCU functional + cycle
//!   models, buffers, external-memory model, control unit, whole-model
//!   simulation, resource (Table III/IV) and power models.
//! * [`runtime`] — PJRT CPU client: loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them —
//!   Python is never on the request path.
//! * [`server`] — the serving front-end: request router, dynamic batcher,
//!   backpressure, metrics (std-thread based; the image vendors no tokio,
//!   see DESIGN.md §5).
//! * [`baseline`] — CPU (live PJRT measurement + Ryzen 5700X model) and
//!   GPU (RTX 2080 Ti model) comparison points for Figs. 11/12.
//! * [`report`] — table formatting and paper-vs-measured reporting.
//! * [`util`] — offline substrates: minimal JSON codec, deterministic
//!   PRNG, micro-bench harness (serde_json / rand / criterion are not in
//!   the vendored registry).

pub mod accel;
pub mod approx;
pub mod baseline;
pub mod fixed;
pub mod model;
pub mod report;
pub mod runtime;
pub mod server;
pub mod util;
