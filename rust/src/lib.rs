//! # swin-fpga — reproduction of "An Efficient FPGA-Based Accelerator for
//! # Swin Transformer" (Liu, Ren, Yin, 2023)
//!
//! The paper's artifact is an FPGA accelerator on a Xilinx XCZU19EG. This
//! crate reproduces the *system* as the Layer-3 Rust coordinator of a
//! three-layer Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * [`fixed`] / [`approx`] — the 16-bit fixed-point datapath and the
//!   paper's shift-add/LUT/LOD approximations (Eqs. 5–12), bit-identical
//!   to `python/compile/fixedpoint.py`; [`approx::peano`] adds the
//!   division/root-free shift-add normalisation the PEANO-style design
//!   uses.
//! * [`model`] — Swin variant configs, the per-layer workload graph, MAC
//!   counts (Eqs. 13–17), BN→linear fusion (Eqs. 2–4) and quantised
//!   weight loading.
//! * [`accel`] — the FPGA, simulated: MMU / SCU / GCU functional + cycle
//!   models, buffers, external-memory model, control unit, the pipeline
//!   schedule IR (the single timing source, see below), whole-model
//!   simulation, resource (Table III/IV) and power models. The SCU/GCU
//!   sit behind the **nonlinear-design layer**
//!   ([`accel::nonlinear::NonlinearDesign`]): each design bundles its
//!   quantised kernels, cycle formulas and resource vector, and
//!   [`accel::AccelConfig::nl_design`] threads the choice through
//!   scheduler timing → pipeline busy intervals → power → serving
//!   estimates in one move:
//!
//!   ```text
//!     NlDesign {Baseline | Quark | Peano}   (AccelConfig::nonlinear)
//!          │ numerics        Scu::softmax / Gcu::gelu (bit-exact)
//!          │ cycles          Scheduler::time_op → PipelineSchedule
//!          │                 (QUARK's shared pipe is arbitrated
//!          │                 per contended window at lowering, not a
//!          │                 flat II=2 surcharge)
//!          │ resources       resources::scu/gcu_resources (Table III)
//!          └ power           power::accelerator_power_w (measured
//!                            per-unit busy fractions × per-design
//!                            resource vectors)
//!   ```
//! * [`runtime`] — PJRT CPU client: loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them —
//!   Python is never on the request path.
//! * [`server`] — the serving front-end: `Engine` trait, continuous
//!   batcher, fleet router, backpressure, metrics (std-thread based; the
//!   image vendors no tokio, see DESIGN.md §5).
//! * [`baseline`] — CPU (live PJRT measurement + Ryzen 5700X model) and
//!   GPU (RTX 2080 Ti model) comparison points for Figs. 11/12.
//! * [`report`] — table formatting and paper-vs-measured reporting.
//! * [`util`] — offline substrates: minimal JSON codec, deterministic
//!   PRNG, micro-bench harness (serde_json / rand / criterion are not in
//!   the vendored registry).
//!
//! ## Serving architecture
//!
//! All launch timing flows from **one** lowered representation, the
//! pipeline schedule IR ([`accel::pipeline::PipelineSchedule`]), and its
//! launch-sequence layer ([`accel::pipeline::SequenceSchedule`]) that
//! places back-to-back launches on one absolute timeline:
//!
//! ```text
//!   model::graph ── Scheduler (op costs) ──▶ PipelineSchedule
//!                         accel::buffers ────▶   │ per-resource busy
//!                    (per-stage prefetch         │ intervals, prefetch
//!                     headroom + window fill)    │ gates, batch replay
//!                                                ▼
//!                                         SequenceSchedule
//!                                  (launch sequences: cold entry vs
//!                                   warm cross-launch prefetch;
//!                                   SequencePlacer streaming appends →
//!                                   steady_launch_cycles fixed point)
//!        ┌──────────────┬──────────────────────┴───────┐
//!        ▼              ▼                              ▼
//!    SimResult       Timeline                      CostTable
//!    (Table V        (Chrome trace,        (cold/warm cycles per bucket,
//!     FPS/GOPS)       multi-launch)         memoized once per variant ×
//!                                           config, shared via Arc)
//!                                        ┌──────┴───────┐
//!                                        ▼              ▼
//!                                    SimEngine       Router /
//!                                    launch_cycles   PjrtEngine
//!                                    steady cost     service_estimate
//!                                    (batch b)       steady_estimate
//!                                                    (+ _cycles u64
//!                                                     fast paths)
//! ```
//!
//! Three ablation flags control the lowering:
//! `AccelConfig::overlap_nonlinear` (SCU/GCU pipelined behind the MMU vs
//! fully serialised), `AccelConfig::overlap_interunit` (cross-unit
//! weight prefetch vs strictly sequential scheduling units — the latter
//! reproduces the pre-pipeline sequential totals exactly, via
//! [`accel::AccelConfig::sequential`]), and
//! `AccelConfig::overlap_interlaunch` (cross-*launch* weight prefetch:
//! launch *N+1*'s stream runs while launch *N* computes, gated by the
//! per-stage buffer headroom of [`accel::buffers::BufferPlan`]; off, a
//! launch sequence costs exactly `Σ launch_cycles(bᵢ)`). The resulting
//! **warm/cold split** — a cold launch pays its first window fill, a
//! warm back-to-back launch does not — gives every [`server::Engine`] a
//! steady-state `steady_estimate` beside the cold `service_estimate`:
//! the fleet router executes back-to-back launches at the warm cost and
//! prices queued backlog with it.
//!
//! ## Model sharding (pipeline parallelism across cards)
//!
//! The 384-input variants overflow one XCZU19EG: the ILB's scores/probs
//! buffers grow as M⁴·heads with the 12×12 windows, so Swin-B/384 needs
//! 1026 BRAM36 and Swin-L/384 1531 against the card's 984
//! ([`accel::buffers::BufferPlan::fits`] is the verdict). The shard
//! layer extends the same IR across a card group:
//!
//! ```text
//!   ShardPlan — greedy stage→card partition under a per-card BRAM
//!       budget (BufferPlan::for_stage_range prices each stage range)
//!         │  swin-b-384 @ 984: stages 0..3 (521) | stage 3 (962)
//!         │  swin-l-384 @ 984: stages 0..3 (770) | stage 3 (1435 !fits)
//!         ▼
//!   ShardedSchedule — one per-card PipelineSchedule per shard, plus a
//!       Resource::Link per cut: the activation map entering the
//!       downstream shard's first stage (2 B × tokens × C_s bytes, the
//!       map a PatchMerge would consume) priced via transfer_cycles —
//!       weights stay card-local, only activations cross the link
//!         ▼
//!   ShardedSequencePlacer — every card on ONE absolute timeline: shard
//!       k+1's first compute is gated on link k landing
//!       (SequencePlacer::append_gated); warm/cold entry rules apply per
//!       card; each link serialises its own transfers, **chunked
//!       per-image**: shard k+1 starts once the first image's activation
//!       lands, so batch-1 timing is bit-identical to the whole-batch
//!       gate and batch>1 can only tighten
//!         ▼
//!   ShardCostTable ──▶ server::ShardedEngine — an Engine like any
//!       other behind the router: cold = Σ shard spans + link
//!       transfers, warm = the slowest component's steady rate
//! ```
//!
//! A single-shard plan lowers **bit-for-bit** to the unsharded schedule
//! (sharding is a strict extension of the timing stack), and the
//! converged steady increment equals the slowest component's rate —
//! `max(shard steadies ∪ link cycles)` — so a sharded pipeline's
//! throughput is the slowest shard's warm throughput while cards overlap
//! *different* launches. The `swin-fpga shard` subcommand prints the
//! partition and cost tables and exports the multi-card Chrome trace
//! (one process per card, links on the upstream card's egress track).
//!
//! Both execution backends sit behind one abstraction,
//! [`server::Engine`] — "submit a batch, get logits plus timing":
//!
//! * [`server::PjrtEngine`] wraps [`runtime::Runtime`] and the AOT
//!   artifact buckets (batch 8/4/2/1); its cold-start
//!   `service_estimate` is warmed from the pipeline schedule
//!   ([`server::ServicePrior`]) until real launches are measured;
//! * [`server::SimEngine`] wraps [`accel::device::VirtualDevice`] and
//!   queries the schedule for batch-*b* launch costs — weights stream
//!   once per launch while compute replays per image, which is exactly
//!   why batching pays on this memory-bound accelerator.
//!
//! On top of the trait sit the batch-formation core and two consumers:
//!
//! * [`server::batcher::CardBatcher`] — per-card queue + launch
//!   decisions, time-unit agnostic (wall-clock nanoseconds or virtual
//!   cycles). Every request carries an SLO class
//!   ([`server::Slo::Interactive`] / [`server::Slo::Batch`]) with a
//!   per-class flush deadline ([`server::SloPolicy`]); a flush fires at
//!   the earliest queued class deadline, and seats fill overdue
//!   interactive → overdue batch (aging, no starvation) → most-urgent
//!   class (bucket homogeneity) → FIFO.
//! * [`server::Server`] — the wall-clock **continuous batcher**: one
//!   executor thread owns one engine; requests are admitted through a
//!   *bounded* channel (backpressure: block or shed) while a launch is
//!   in flight, and the executor replays its `CardBatcher`'s decisions
//!   in real time. The seed's stop-the-world accumulate/flush cycle is
//!   retained as [`server::BatchMode::StopTheWorld`] for the ablation
//!   bench.
//! * [`server::router::Router`] — the fleet: one `CardBatcher` **per
//!   card** over `Vec<Box<dyn Engine>>` in virtual time. JSQ policies
//!   (least-loaded / power-of-two) compare **modelled backlog** —
//!   residual busy time plus the card's queue priced through
//!   [`server::decompose`] + `service_estimate`
//!   ([`server::router::LoadModel::Backlog`]); the raw busy horizon is
//!   kept as [`server::router::LoadModel::BusyHorizon`] for the
//!   ablation. [`server::router::LoadModel::Energy`] prices J/inference
//!   into the same signal: [`server::engine::CardPrices`] carries
//!   per-bucket launch energy (busy-fraction-weighted dynamic + static
//!   over the launch span, cold and warm, from
//!   [`accel::power::span_power_w`] — the same term-for-term expression
//!   as the whole-run estimate) and the router adds
//!   `energy_weight` cycles of penalty per mJ of a card's *marginal*
//!   energy for the next arrival. Idle-card power gating is modelled as
//!   a cold-entry analogue: a gated card pays a wake-up fill
//!   ([`accel::pipeline::PipelineSchedule::wakeup_fill_cycles`]) before
//!   its first launch, priced into `load_cycles` exactly like the
//!   cold/warm split — and an ungated fleet instead bills idle static
//!   draw into `fleet_energy_uj` over the run horizon. At zero weight
//!   with gating off, `Energy` reproduces `Backlog` **bit-for-bit**.
//!   Multi-card experiments run identically over simulated
//!   cards and PJRT backends, including heterogeneous (mixed Swin-T/S)
//!   fleets. Least-loaded picks go through an O(log N) lazily-updated
//!   index pinned bit-identical to the O(N) scan (lowest-index
//!   tie-break preserved).
//! * [`server::router::ShardedRouter`] — the same fleet partitioned
//!   into per-shard calendars run on `std::thread::scope` workers:
//!   arrivals are assigned to shards by load summaries snapshotted at
//!   deterministic epoch boundaries, per-shard arrival substreams come
//!   from a counter-based PRNG keyed by `(seed, shard)`
//!   ([`server::workload::ShardArrivalGen`]), and per-shard completion
//!   streams are k-way-merged at drain — so results are **bit-identical
//!   for every thread count** and, with one shard, to the
//!   single-threaded calendar (billion-arrival fleet experiments,
//!   `rust/benches/fleet1b.rs`).
//! * [`server::fault`] — deterministic fault injection over the same
//!   virtual clock: a [`server::FaultPlan`] schedules per-card
//!   fail-stop crashes, launch-cost degradations, elastic joins and
//!   graceful leaves as calendar events interleaved with launches in
//!   `(cycle, card)` order. Cards carry a health state
//!   ([`server::CardHealth`]: up / degraded / draining / down); crashes
//!   retract in-flight results and redispatch them to survivors with
//!   their **original enqueue ticks** under a per-request retry budget;
//!   leaves drain the queue exactly once without touching budgets. The
//!   **fault-substream determinism contract**: `FaultPlan::random`
//!   derives each card's events from a counter-based PRNG substream
//!   keyed by `(seed, card)` ([`util::prng::CounterRng`]) — a pure
//!   function of the pair — so a plan splits across shards
//!   (`FaultPlan::subplan`) without changing a single event, and every
//!   faulted run is bit-identical across thread counts. A zero-event
//!   plan is **inert**: bit-identical to running with no plan at all.
//!
//! ```text
//!              requests (class-tagged: interactive | batch)
//!                               │
//!              ShardedRouter ── pick shard by min epoch-snapshot
//!                               load summary (deterministic)
//!            ┌─────────────────┴┬────────────────────┐
//!            ▼                  ▼                    ▼
//!        Shard #0           Shard #1                 …
//!        (thread 0)         (thread 1)
//!            │                  │
//!        Router ── pick card by min modelled backlog = residual busy
//!            │         + Σ service_estimate(decompose(queue))
//!            │    FaultPlan ── crash/degrade/join/leave events fire
//!            │         on the same calendar; health gates picks,
//!            │         retries redispatch with original enqueue ticks
//!      ┌─────┴─────┐      ┌─────┴─────┐
//!      ▼           ▼      ▼           ▼
//! CardBatcher CardBatcher CardBatcher CardBatcher
//! (bounded Q,  SLO flush)     …           …
//!      ▼           ▼      ▼           ▼
//!  Engine #0   Engine #1  Engine #2   Engine #3
//!  (swin-t)    (swin-t)   (swin-s)    (swin-s)
//!   up/degraded/draining/down — health census in FleetStats
//!      └───────────┴──────────┴───────────┘
//!        drain: deterministic k-way merge by (finish, idx)
//! ```
//!
//! **Fault model** (fail-stop, no partial results): a crash at cycle
//! `T` retracts every result with `finish > T` on that card — results
//! are only observable at launch completion, so there are no
//! partial-launch outputs to reason about — and never refunds booked
//! energy or busy cycles (the work physically happened). Degradation
//! scales launch *compute* (cold service and warm steady launches) by
//! `factor/100`, not the wake-up fill. A leave stops admission,
//! redistributes the queue, lets in-flight work finish, then settles
//! down. Conservation is asserted property-style: every submitted
//! request is served, shed at admission, or counted lost — exactly
//! once (`rust/tests/schedule_properties.rs`).
//!
//! Per-request metrics ([`server::Metrics`]) report p50/p95/p99 latency
//! (overall and per SLO class) over **fixed-size reservoirs**
//! ([`util::stats::Reservoir`] — long-running serves hold O(cap)
//! memory), the batch-occupancy histogram, queue depth and shed counts,
//! and are exportable — together with per-card queue/class gauges, a
//! live shed counter and the modelled schedule summary — through a
//! scrape-able JSON endpoint ([`server::ScrapeServer`], CLI flag
//! `--metrics-port`). The `swin-fpga fleet` subcommand runs the queued
//! fleet experiment (backlog vs busy-horizon, plus the energy-aware
//! model via `--energy-weight` / `--gate`, with a J/inference column
//! billed from the launch-span energy model) from the CLI;
//! `cargo bench --bench fleet_scaling` sweeps the energy weight into a
//! latency-vs-J/inference Pareto table (`PARETO_energy.json`).

pub mod accel;
pub mod approx;
pub mod baseline;
pub mod fixed;
pub mod model;
pub mod report;
pub mod runtime;
pub mod server;
pub mod util;
