//! # swin-fpga — reproduction of "An Efficient FPGA-Based Accelerator for
//! # Swin Transformer" (Liu, Ren, Yin, 2023)
//!
//! The paper's artifact is an FPGA accelerator on a Xilinx XCZU19EG. This
//! crate reproduces the *system* as the Layer-3 Rust coordinator of a
//! three-layer Rust + JAX + Pallas stack (see DESIGN.md):
//!
//! * [`fixed`] / [`approx`] — the 16-bit fixed-point datapath and the
//!   paper's shift-add/LUT/LOD approximations (Eqs. 5–12), bit-identical
//!   to `python/compile/fixedpoint.py`.
//! * [`model`] — Swin variant configs, the per-layer workload graph, MAC
//!   counts (Eqs. 13–17), BN→linear fusion (Eqs. 2–4) and quantised
//!   weight loading.
//! * [`accel`] — the FPGA, simulated: MMU / SCU / GCU functional + cycle
//!   models, buffers, external-memory model, control unit, the pipeline
//!   schedule IR (the single timing source, see below), whole-model
//!   simulation, resource (Table III/IV) and power models.
//! * [`runtime`] — PJRT CPU client: loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them —
//!   Python is never on the request path.
//! * [`server`] — the serving front-end: `Engine` trait, continuous
//!   batcher, fleet router, backpressure, metrics (std-thread based; the
//!   image vendors no tokio, see DESIGN.md §5).
//! * [`baseline`] — CPU (live PJRT measurement + Ryzen 5700X model) and
//!   GPU (RTX 2080 Ti model) comparison points for Figs. 11/12.
//! * [`report`] — table formatting and paper-vs-measured reporting.
//! * [`util`] — offline substrates: minimal JSON codec, deterministic
//!   PRNG, micro-bench harness (serde_json / rand / criterion are not in
//!   the vendored registry).
//!
//! ## Serving architecture
//!
//! All launch timing flows from **one** lowered representation, the
//! pipeline schedule IR ([`accel::pipeline::PipelineSchedule`]), and its
//! launch-sequence layer ([`accel::pipeline::SequenceSchedule`]) that
//! places back-to-back launches on one absolute timeline:
//!
//! ```text
//!   model::graph ── Scheduler (op costs) ──▶ PipelineSchedule
//!                         accel::buffers ────▶   │ per-resource busy
//!                    (per-stage prefetch         │ intervals, prefetch
//!                     headroom + window fill)    │ gates, batch replay
//!                                                ▼
//!                                         SequenceSchedule
//!                                  (launch sequences: cold entry vs
//!                                   warm cross-launch prefetch;
//!                                   SequencePlacer streaming appends →
//!                                   steady_launch_cycles fixed point)
//!        ┌──────────────┬──────────────────────┴───────┐
//!        ▼              ▼                              ▼
//!    SimResult       Timeline                      CostTable
//!    (Table V        (Chrome trace,        (cold/warm cycles per bucket,
//!     FPS/GOPS)       multi-launch)         memoized once per variant ×
//!                                           config, shared via Arc)
//!                                        ┌──────┴───────┐
//!                                        ▼              ▼
//!                                    SimEngine       Router /
//!                                    launch_cycles   PjrtEngine
//!                                    steady cost     service_estimate
//!                                    (batch b)       steady_estimate
//!                                                    (+ _cycles u64
//!                                                     fast paths)
//! ```
//!
//! Three ablation flags control the lowering:
//! `AccelConfig::overlap_nonlinear` (SCU/GCU pipelined behind the MMU vs
//! fully serialised), `AccelConfig::overlap_interunit` (cross-unit
//! weight prefetch vs strictly sequential scheduling units — the latter
//! reproduces the pre-pipeline sequential totals exactly, via
//! [`accel::AccelConfig::sequential`]), and
//! `AccelConfig::overlap_interlaunch` (cross-*launch* weight prefetch:
//! launch *N+1*'s stream runs while launch *N* computes, gated by the
//! per-stage buffer headroom of [`accel::buffers::BufferPlan`]; off, a
//! launch sequence costs exactly `Σ launch_cycles(bᵢ)`). The resulting
//! **warm/cold split** — a cold launch pays its first window fill, a
//! warm back-to-back launch does not — gives every [`server::Engine`] a
//! steady-state `steady_estimate` beside the cold `service_estimate`:
//! the fleet router executes back-to-back launches at the warm cost and
//! prices queued backlog with it.
//!
//! Both execution backends sit behind one abstraction,
//! [`server::Engine`] — "submit a batch, get logits plus timing":
//!
//! * [`server::PjrtEngine`] wraps [`runtime::Runtime`] and the AOT
//!   artifact buckets (batch 8/4/2/1); its cold-start
//!   `service_estimate` is warmed from the pipeline schedule
//!   ([`server::ServicePrior`]) until real launches are measured;
//! * [`server::SimEngine`] wraps [`accel::device::VirtualDevice`] and
//!   queries the schedule for batch-*b* launch costs — weights stream
//!   once per launch while compute replays per image, which is exactly
//!   why batching pays on this memory-bound accelerator.
//!
//! On top of the trait sit the batch-formation core and two consumers:
//!
//! * [`server::batcher::CardBatcher`] — per-card queue + launch
//!   decisions, time-unit agnostic (wall-clock nanoseconds or virtual
//!   cycles). Every request carries an SLO class
//!   ([`server::Slo::Interactive`] / [`server::Slo::Batch`]) with a
//!   per-class flush deadline ([`server::SloPolicy`]); a flush fires at
//!   the earliest queued class deadline, and seats fill overdue
//!   interactive → overdue batch (aging, no starvation) → most-urgent
//!   class (bucket homogeneity) → FIFO.
//! * [`server::Server`] — the wall-clock **continuous batcher**: one
//!   executor thread owns one engine; requests are admitted through a
//!   *bounded* channel (backpressure: block or shed) while a launch is
//!   in flight, and the executor replays its `CardBatcher`'s decisions
//!   in real time. The seed's stop-the-world accumulate/flush cycle is
//!   retained as [`server::BatchMode::StopTheWorld`] for the ablation
//!   bench.
//! * [`server::router::Router`] — the fleet: one `CardBatcher` **per
//!   card** over `Vec<Box<dyn Engine>>` in virtual time. JSQ policies
//!   (least-loaded / power-of-two) compare **modelled backlog** —
//!   residual busy time plus the card's queue priced through
//!   [`server::decompose`] + `service_estimate`
//!   ([`server::router::LoadModel::Backlog`]); the raw busy horizon is
//!   kept as [`server::router::LoadModel::BusyHorizon`] for the
//!   ablation. Multi-card experiments run identically over simulated
//!   cards and PJRT backends, including heterogeneous (mixed Swin-T/S)
//!   fleets.
//!
//! ```text
//!              requests (class-tagged: interactive | batch)
//!                               │
//!                    Router ── pick card by min
//!                    modelled backlog = residual busy
//!                      + Σ service_estimate(decompose(queue))
//!            ┌─────────────┬─┴───────────┬─────────────┐
//!            ▼             ▼             ▼             ▼
//!       CardBatcher   CardBatcher   CardBatcher   CardBatcher
//!       (bounded Q,   (bounded Q,       …              …
//!        SLO flush)    SLO flush)
//!            ▼             ▼             ▼             ▼
//!        Engine #0     Engine #1     Engine #2     Engine #3
//!        (swin-t)      (swin-t)      (swin-s)      (swin-s)
//! ```
//!
//! Per-request metrics ([`server::Metrics`]) report p50/p95/p99 latency
//! (overall and per SLO class) over **fixed-size reservoirs**
//! ([`util::stats::Reservoir`] — long-running serves hold O(cap)
//! memory), the batch-occupancy histogram, queue depth and shed counts,
//! and are exportable — together with per-card queue/class gauges, a
//! live shed counter and the modelled schedule summary — through a
//! scrape-able JSON endpoint ([`server::ScrapeServer`], CLI flag
//! `--metrics-port`). The `swin-fpga fleet` subcommand runs the queued
//! fleet experiment (backlog vs busy-horizon) from the CLI.

pub mod accel;
pub mod approx;
pub mod baseline;
pub mod fixed;
pub mod model;
pub mod report;
pub mod runtime;
pub mod server;
pub mod util;
