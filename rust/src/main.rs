//! swin-fpga CLI — leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is not in the vendored registry):
//!
//! ```text
//! swin-fpga simulate [--variant swin-t|swin-s|swin-b|swin-micro] [--images N]
//!                    [--design baseline|quark|peano]
//! swin-fpga serve    [--artifacts DIR | --sim VARIANT] [--requests N]
//!                    [--rate RPS] [--batch-max N] [--metrics-port P]
//!                    [--slo-interactive-ms M] [--slo-batch-ms M]
//!                    [--interactive-share F]
//! swin-fpga fleet    [--cards N] [--variant V | --mixed] [--requests N]
//!                    [--rate RPS] [--bursty] [--interactive-share F]
//!                    [--policy round-robin|least-loaded|power-of-two]
//!                    [--threads N] [--shards S]
//!                    [--energy-weight W] [--gate]
//! swin-fpga trace    [--variant V] [--batch N] [--launches N] [--sequential]
//!                    [--out PATH]
//! swin-fpga shard    [--variant V] [--budget BRAM36] [--batch N] [--launches N]
//!                    [--out PATH] [--fleet] [--requests N] [--rate RPS]
//! swin-fpga report   [--artifacts DIR]      # all paper tables/figures
//! swin-fpga selftest [--artifacts DIR]      # runtime + simulator cross-check
//! ```

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use swin_fpga::model::config::{SwinVariant, PAPER_VARIANTS};
use swin_fpga::{accel, baseline, report, runtime, server};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

/// `--design baseline|quark|peano` (default: the paper's baseline).
fn parse_design(flags: &HashMap<String, String>) -> Result<accel::nonlinear::NlDesign, String> {
    match flags.get("design") {
        None => Ok(accel::nonlinear::NlDesign::Baseline),
        Some(s) => accel::nonlinear::NlDesign::by_name(s)
            .ok_or_else(|| format!("unknown design {s} (baseline|quark|peano)")),
    }
}

fn usage() -> &'static str {
    "usage: swin-fpga <simulate|serve|fleet|trace|shard|report|selftest> [flags]\n\
     \n\
     simulate  --variant <swin-t|swin-s|swin-b|swin-micro> [--images N]\n\
     \x20         [--design baseline|quark|peano]   # nonlinear-unit design\n\
     serve     [--artifacts DIR | --sim VARIANT] [--requests N] [--rate RPS]\n\
     \x20         [--batch-max N] [--metrics-port P]\n\
     \x20         [--slo-interactive-ms M] [--slo-batch-ms M] [--interactive-share F]\n\
     fleet     [--cards N] [--variant V | --mixed] [--requests N] [--rate RPS]\n\
     \x20         [--bursty] [--interactive-share F]\n\
     \x20         [--policy round-robin|least-loaded|power-of-two]\n\
     \x20         [--threads N] [--shards S]   # sharded router; results are\n\
     \x20         \x20                          # identical for every N (asserted);\n\
     \x20         \x20                          # S defaults to min(threads, cards)\n\
     \x20         [--energy-weight W] [--gate] # adds an energy-routed row:\n\
     \x20         \x20                          # W cycles of penalty per mJ of\n\
     \x20         \x20                          # marginal energy; --gate also\n\
     \x20         \x20                          # power-gates idle cards (wake-up\n\
     \x20         \x20                          # fill charged on cold launches)\n\
     \x20         [--faults SPEC] [--retry-budget N]  # deterministic fault plan:\n\
     \x20         \x20                          # none | rand:SEED:BUDGET |\n\
     \x20         \x20                          # crash:CARD:AT_MS / leave:CARD:AT_MS /\n\
     \x20         \x20                          # join:CARD:AT_MS /\n\
     \x20         \x20                          # degrade:CARD:AT_MS:PCT:UNTIL_MS\n\
     \x20         \x20                          # joined with ';'\n\
     trace     [--variant V] [--batch N] [--launches N] [--sequential] [--out PATH]\n\
     \x20         [--design baseline|quark|peano]\n\
     shard     [--variant V] [--budget BRAM36] [--batch N] [--launches N]\n\
     \x20         [--out PATH] [--fleet] [--requests N] [--rate RPS]\n\
     report    [--artifacts DIR]\n\
     selftest  [--artifacts DIR]\n"
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{}", usage());
        return ExitCode::from(2);
    };
    let flags = parse_flags(&args[1..]);
    let artifacts = PathBuf::from(
        flags
            .get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".to_string()),
    );

    let result = match cmd.as_str() {
        "simulate" => {
            let name = flags
                .get("variant")
                .map(String::as_str)
                .unwrap_or("swin-t");
            let Some(variant) = SwinVariant::by_name(name) else {
                eprintln!("unknown variant {name}");
                return ExitCode::from(2);
            };
            let images: usize = flags
                .get("images")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1);
            let design = match parse_design(&flags) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("{e}");
                    return ExitCode::from(2);
                }
            };
            cmd_simulate(variant, images, design)
        }
        "serve" => {
            let requests = flags
                .get("requests")
                .and_then(|s| s.parse().ok())
                .unwrap_or(64);
            let rate = flags
                .get("rate")
                .and_then(|s| s.parse().ok())
                .unwrap_or(50.0);
            let batch_max = flags
                .get("batch-max")
                .and_then(|s| s.parse().ok())
                .unwrap_or(8);
            let metrics_port: Option<u16> =
                flags.get("metrics-port").and_then(|s| s.parse().ok());
            let slo = parse_slo(&flags);
            let share = flags
                .get("interactive-share")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1.0);
            match flags.get("sim") {
                Some(name) => {
                    let Some(variant) = SwinVariant::by_name(name) else {
                        eprintln!("unknown variant {name}");
                        return ExitCode::from(2);
                    };
                    cmd_serve_sim(variant, requests, rate, batch_max, metrics_port, slo, share)
                }
                None => {
                    cmd_serve(&artifacts, requests, rate, batch_max, metrics_port, slo, share)
                }
            }
        }
        "fleet" => {
            let cards: usize = flags.get("cards").and_then(|s| s.parse().ok()).unwrap_or(4);
            if cards == 0 {
                eprintln!("fleet needs at least one card");
                return ExitCode::from(2);
            }
            let requests = flags
                .get("requests")
                .and_then(|s| s.parse().ok())
                .unwrap_or(400);
            let rate = flags
                .get("rate")
                .and_then(|s| s.parse().ok())
                .unwrap_or(120.0);
            let share = flags
                .get("interactive-share")
                .and_then(|s| s.parse().ok())
                .unwrap_or(0.5);
            let bursty = flags.contains_key("bursty");
            let mixed = flags.contains_key("mixed");
            let policy = match flags.get("policy").map(String::as_str) {
                None | Some("least-loaded") | Some("ll") => server::router::Policy::LeastLoaded,
                Some("round-robin") | Some("rr") => server::router::Policy::RoundRobin,
                Some("power-of-two") | Some("p2") => server::router::Policy::PowerOfTwo,
                Some(other) => {
                    eprintln!("unknown policy {other}");
                    return ExitCode::from(2);
                }
            };
            let variant = match flags.get("variant").map(String::as_str) {
                Some(name) => match SwinVariant::by_name(name) {
                    Some(v) => v,
                    None => {
                        eprintln!("unknown variant {name}");
                        return ExitCode::from(2);
                    }
                },
                None => SwinVariant::by_name("swin-t").unwrap(),
            };
            let threads: usize = flags
                .get("threads")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1)
                .max(1);
            // default: auto-tune to min(threads, cards) — the unique
            // count that saturates the worker threads without splitting
            // finer than the card partition (see ShardSpec::auto);
            // --shards overrides for determinism experiments
            let shards: usize = flags
                .get("shards")
                .and_then(|s| s.parse().ok())
                .unwrap_or_else(|| {
                    server::router::ShardSpec::auto(threads, cards, 10.0).shards
                })
                .max(1);
            let energy_weight: u64 = flags
                .get("energy-weight")
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let gate = flags.contains_key("gate");
            let faults = match flags.get("faults") {
                Some(spec) => match server::FaultPlan::parse(spec, cards) {
                    Ok(mut plan) => {
                        if let Some(b) = flags.get("retry-budget").and_then(|s| s.parse().ok())
                        {
                            plan.retry_budget = b;
                        }
                        Some(plan)
                    }
                    Err(e) => {
                        eprintln!("bad --faults spec: {e}");
                        return ExitCode::from(2);
                    }
                },
                None => None,
            };
            cmd_fleet(
                cards, variant, mixed, requests, rate, bursty, share, policy, threads, shards,
                energy_weight, gate, faults,
            )
        }
        "trace" => {
            let name = flags
                .get("variant")
                .map(String::as_str)
                .unwrap_or("swin-t");
            let Some(variant) = SwinVariant::by_name(name) else {
                eprintln!("unknown variant {name}");
                return ExitCode::from(2);
            };
            let batch: usize = flags
                .get("batch")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1);
            let launches: usize = flags
                .get("launches")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1);
            if launches == 0 {
                eprintln!("trace needs at least one launch");
                return ExitCode::from(2);
            }
            let sequential = flags.contains_key("sequential");
            let out = flags.get("out").cloned();
            let design = match parse_design(&flags) {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("{e}");
                    return ExitCode::from(2);
                }
            };
            cmd_trace(variant, batch, launches, sequential, out.as_deref(), design)
        }
        "shard" => {
            let name = flags
                .get("variant")
                .map(String::as_str)
                .unwrap_or("swin-l-384");
            let Some(variant) = SwinVariant::by_name(name) else {
                eprintln!("unknown variant {name}");
                return ExitCode::from(2);
            };
            let budget: usize = flags
                .get("budget")
                .and_then(|s| s.parse().ok())
                .unwrap_or(accel::buffers::XCZU19EG_BRAM36);
            let batch: usize = flags
                .get("batch")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1);
            let launches: usize = flags
                .get("launches")
                .and_then(|s| s.parse().ok())
                .unwrap_or(4);
            if launches == 0 {
                eprintln!("shard needs at least one launch");
                return ExitCode::from(2);
            }
            let out = flags.get("out").cloned();
            let fleet = flags.contains_key("fleet");
            let requests = flags
                .get("requests")
                .and_then(|s| s.parse().ok())
                .unwrap_or(200);
            let rate = flags
                .get("rate")
                .and_then(|s| s.parse().ok())
                .unwrap_or(120.0);
            cmd_shard(variant, budget, batch, launches, out.as_deref(), fleet, requests, rate)
        }
        "report" => cmd_report(&artifacts),
        "selftest" => cmd_selftest(&artifacts),
        _ => {
            eprint!("{}", usage());
            return ExitCode::from(2);
        }
    };

    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_simulate(
    variant: &'static SwinVariant,
    images: usize,
    design: accel::nonlinear::NlDesign,
) -> anyhow::Result<()> {
    let cfg = accel::AccelConfig::paper().nonlinear(design);
    let sim = accel::sim::Simulator::new(variant, cfg);
    let r = sim.simulate_inference();
    println!("{}", report::render_sim_result(variant, &r));
    if images > 1 {
        println!(
            "batch of {images}: {:.1} ms total @ {:.1} FPS steady-state",
            images as f64 * r.latency_ms(),
            r.fps()
        );
    }
    Ok(())
}

/// Spin up the scrape endpoint (when asked) around a serving run. The
/// model summary is built lazily so plain runs pay no schedule-lowering
/// cost for an endpoint they never start.
fn with_metrics_endpoint<S, F>(
    model_summary: S,
    metrics_port: Option<u16>,
    run: F,
) -> anyhow::Result<server::Metrics>
where
    S: FnOnce() -> swin_fpga::util::json::Json,
    F: FnOnce(Option<std::sync::Arc<server::MetricsHub>>) -> anyhow::Result<server::Metrics>,
{
    match metrics_port {
        Some(port) => {
            let hub = server::MetricsHub::new(model_summary());
            // loopback by default: metrics are operational detail, not a
            // service to expose on every interface
            let endpoint =
                server::ScrapeServer::bind(&format!("127.0.0.1:{port}"), hub.clone())?;
            println!("metrics endpoint: http://{}/metrics.json", endpoint.addr());
            let m = run(Some(hub));
            endpoint.shutdown();
            m
        }
        None => run(None),
    }
}

/// `--slo-interactive-ms` / `--slo-batch-ms` → per-class flush deadlines
/// (either flag alone keeps the default for the other class).
fn parse_slo(flags: &HashMap<String, String>) -> Option<server::SloPolicy> {
    let i = flags.get("slo-interactive-ms").and_then(|s| s.parse().ok());
    let b = flags.get("slo-batch-ms").and_then(|s| s.parse().ok());
    if i.is_none() && b.is_none() {
        return None;
    }
    let d = server::SloPolicy::default();
    Some(server::SloPolicy {
        interactive_max_wait: i
            .map(std::time::Duration::from_millis)
            .unwrap_or(d.interactive_max_wait),
        batch_max_wait: b
            .map(std::time::Duration::from_millis)
            .unwrap_or(d.batch_max_wait),
    })
}

#[allow(clippy::too_many_arguments)]
fn cmd_serve(
    artifacts: &std::path::Path,
    requests: usize,
    rate: f64,
    batch_max: usize,
    metrics_port: Option<u16>,
    slo: Option<server::SloPolicy>,
    interactive_share: f64,
) -> anyhow::Result<()> {
    let policy = server::BatchPolicy {
        max_batch: batch_max,
        slo,
        ..Default::default()
    };
    // model summary for the endpoint, when the manifest names a variant
    let summary = || {
        runtime::Manifest::load(artifacts)
            .ok()
            .and_then(|m| {
                m.artifacts
                    .values()
                    .find_map(|a| a.variant.clone())
                    .and_then(|n| SwinVariant::by_name(&n))
            })
            .map(|v| {
                accel::pipeline::PipelineSchedule::for_variant(v, accel::AccelConfig::paper())
                    .summary_json()
            })
            .unwrap_or(swin_fpga::util::json::Json::Null)
    };
    let m = with_metrics_endpoint(summary, metrics_port, |hub| {
        server::run_demo_metrics_observed(
            artifacts,
            requests,
            rate,
            policy.clone(),
            interactive_share,
            hub,
        )
    })?;
    println!("{m}");
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn cmd_serve_sim(
    variant: &'static SwinVariant,
    requests: usize,
    rate: f64,
    batch_max: usize,
    metrics_port: Option<u16>,
    slo: Option<server::SloPolicy>,
    interactive_share: f64,
) -> anyhow::Result<()> {
    let cfg = accel::AccelConfig::paper();
    let policy = server::BatchPolicy {
        max_batch: batch_max,
        slo,
        ..Default::default()
    };
    let summary =
        || accel::pipeline::PipelineSchedule::for_variant(variant, cfg.clone()).summary_json();
    let m = with_metrics_endpoint(summary, metrics_port, |hub| {
        server::run_demo_metrics_sim_observed(
            variant,
            cfg.clone(),
            0.05,
            requests,
            rate,
            policy.clone(),
            interactive_share,
            hub,
        )
    })?;
    println!("{m}");
    Ok(())
}

/// Queued fleet experiment in virtual time: per-card continuous batchers
/// behind the router, backlog-aware JSQ vs the busy-horizon baseline,
/// each under cold (`overlap_interlaunch = false`) and warm launch
/// timing — the cross-launch-prefetch ablation. With `--energy-weight`
/// (and/or `--gate`) an energy-routed row rides along: marginal J/inference
/// priced into the load signal at `W` cycles per mJ, idle cards optionally
/// power-gated (wake-up fill charged as a cold-entry analogue).
#[allow(clippy::too_many_arguments)]
fn cmd_fleet(
    cards: usize,
    variant: &'static SwinVariant,
    mixed: bool,
    requests: usize,
    rate: f64,
    bursty: bool,
    interactive_share: f64,
    policy: server::router::Policy,
    threads: usize,
    shards: usize,
    energy_weight: u64,
    gate: bool,
    faults: Option<server::FaultPlan>,
) -> anyhow::Result<()> {
    use swin_fpga::server::router::{
        fleet_percentiles, FaultCounters, FleetPolicy, LoadModel, Router, ShardSpec,
        ShardedRouter,
    };
    use swin_fpga::server::workload::{classed_arrivals, Arrival};
    use swin_fpga::server::{Engine, SimEngine};

    let use_sharded = threads > 1 || shards > 1;

    let small = SwinVariant::by_name("swin-s").unwrap();
    let kind = if bursty {
        Arrival::Bursty {
            high: rate * 3.0,
            burst_s: 0.2,
            gap_s: 0.4,
        }
    } else {
        Arrival::Poisson { rate }
    };
    let arr = classed_arrivals(kind, requests, interactive_share, 29);
    let fleet_label = if mixed {
        format!("{} + {}", variant.name, small.name)
    } else {
        variant.name.to_string()
    };
    let title = if use_sharded {
        format!(
            "fleet: {cards} cards ({fleet_label}), {} policy, {requests} requests, {} arrivals, \
             {shards} shards x {threads} threads",
            policy.name(),
            if bursty { "bursty" } else { "poisson" },
        )
    } else {
        format!(
            "fleet: {cards} cards ({fleet_label}), {} policy, {requests} requests, {} arrivals",
            policy.name(),
            if bursty { "bursty" } else { "poisson" },
        )
    };
    let mut t = swin_fpga::report::Table::new(
        &title,
        &[
            "load signal",
            "timing",
            "p50 ms",
            "p99 ms",
            "interactive p99",
            "batch p99",
            "J/inf",
        ],
    );
    // the warm-vs-cold ablation: cross-launch prefetch off (every launch
    // cold) vs on (back-to-back launches at the warm steady-state cost)
    let timings = [
        ("cold", accel::AccelConfig::paper().interlaunch(false)),
        ("warm", accel::AccelConfig::paper()),
    ];
    // one shared cost table per (variant actually in the fleet, timing
    // config) — lowered and warm-converged once, reused across the load
    // signals and every card
    let fleet_variants: Vec<&SwinVariant> =
        if mixed { vec![variant, small] } else { vec![variant] };
    let timing_tables: Vec<Vec<std::sync::Arc<accel::pipeline::CostTable>>> = timings
        .iter()
        .map(|(_, tcfg)| {
            fleet_variants
                .iter()
                .map(|v| {
                    std::sync::Arc::new(accel::pipeline::CostTable::for_variant(
                        v,
                        tcfg.clone(),
                        &swin_fpga::server::BUCKET_SIZES,
                    ))
                })
                .collect()
        })
        .collect();
    let mut loads = vec![LoadModel::BusyHorizon, LoadModel::Backlog];
    if energy_weight > 0 || gate {
        loads.push(LoadModel::Energy);
    }
    let mut fault_lines: Vec<String> = Vec::new();
    for load in loads {
        for ((label, _), tables) in timings.iter().zip(&timing_tables) {
            let engines: Vec<Box<dyn Engine + Send>> = (0..cards)
                .map(|i| {
                    let which = usize::from(mixed && i % 2 == 1);
                    let v = if which == 1 { small } else { variant };
                    Box::new(SimEngine::with_table(
                        i,
                        v,
                        std::sync::Arc::clone(&tables[which]),
                        0.0,
                    )) as Box<dyn Engine + Send>
                })
                .collect();
            // energy routing knobs only bite on the Energy row; the
            // latency-only rows keep weight 0 / gating off so they stay
            // the PR-3/PR-4 baselines bit-for-bit
            let (weight, gating) = if load == LoadModel::Energy {
                (energy_weight, gate)
            } else {
                (0, false)
            };
            let (comps, fleet_uj, fc) = if use_sharded {
                let mut s = ShardedRouter::with_fleet(
                    engines,
                    policy,
                    FleetPolicy::default(),
                    ShardSpec::new(shards, 10.0),
                )
                .with_load(load)
                .with_energy_weight(weight)
                .with_idle_gating(gating);
                if let Some(plan) = &faults {
                    s = s.with_faults(plan.clone());
                }
                let comps = s.run_classed(&arr, threads);
                let fc = s.fault_counters();
                // the determinism contract, checked on every CLI run:
                // the thread count is execution detail only — fault
                // counters included
                let single = s.run_classed(&arr, 1);
                assert!(
                    comps.len() == single.len()
                        && comps.iter().zip(&single).all(|(a, b)| {
                            (a.idx, a.device, a.arrival, a.start, a.finish)
                                == (b.idx, b.device, b.arrival, b.start, b.finish)
                        })
                        && fc == s.fault_counters(),
                    "threads={threads} diverged from the single-threaded stream"
                );
                let horizon = comps.iter().map(|c| c.finish).max().unwrap_or(0);
                let uj = s.fleet_energy_uj(horizon);
                (comps, uj, fc)
            } else {
                let engines = engines
                    .into_iter()
                    .map(|e| {
                        let e: Box<dyn Engine> = e;
                        e
                    })
                    .collect();
                let mut r = Router::from_engines(engines, policy)
                    .with_load(load)
                    .with_energy_weight(weight)
                    .with_idle_gating(gating);
                if let Some(plan) = &faults {
                    r.set_fault_plan(plan.clone());
                }
                let comps = r.run_classed(&arr);
                let horizon = comps.iter().map(|c| c.finish).max().unwrap_or(0);
                let uj = r.fleet_energy_uj(horizon);
                let fc = r.fault_counters();
                (comps, uj, fc)
            };
            if faults.is_some() && fc != FaultCounters::default() {
                fault_lines.push(format!(
                    "  {}/{label}: {} retries, {} redispatched, {} crash-lost, {} lost \
                     ({} served, {} submitted)",
                    load.name(),
                    fc.retries,
                    fc.redispatched,
                    fc.crash_lost,
                    fc.lost,
                    comps.len(),
                    arr.len(),
                ));
            }
            let [p50, p99, inter_p99, batch_p99] = fleet_percentiles(&comps);
            let j_per_inf = fleet_uj as f64 / 1e6 / comps.len().max(1) as f64;
            t.row(&[
                load.name().to_string(),
                (*label).to_string(),
                format!("{p50:.1}"),
                format!("{p99:.1}"),
                format!("{inter_p99:.1}"),
                format!("{batch_p99:.1}"),
                format!("{j_per_inf:.2}"),
            ]);
        }
    }
    println!("{t}");
    if let Some(plan) = &faults {
        println!(
            "fault plan: {} event(s) across {cards} cards, retry budget {} \
             (deterministic — same plan on every thread count, asserted above)",
            plan.events.iter().map(Vec::len).sum::<usize>(),
            plan.retry_budget,
        );
        for l in &fault_lines {
            println!("{l}");
        }
        if fault_lines.is_empty() {
            println!("  no fault fired within the run horizon");
        }
    }
    if energy_weight > 0 || gate {
        println!(
            "energy routing: {energy_weight} cycles of load penalty per mJ of marginal \
             energy; idle cards {}",
            if gate {
                "power-gated (wake-up fill charged on cold launches)"
            } else {
                "draw static power (billed into J/inf over the run horizon)"
            },
        );
    }
    if use_sharded {
        println!(
            "sharded router: {shards} shards on {threads} threads reproduced the \
             single-threaded completion stream bit-for-bit (epoch-snapshot routing)"
        );
    }
    Ok(())
}

fn cmd_trace(
    variant: &'static SwinVariant,
    batch: usize,
    launches: usize,
    sequential: bool,
    out: Option<&str>,
    design: accel::nonlinear::NlDesign,
) -> anyhow::Result<()> {
    use swin_fpga::accel::pipeline::{PipelineSchedule, Resource};
    use swin_fpga::accel::trace::Timeline;
    let cfg = if sequential {
        accel::AccelConfig::paper().sequential()
    } else {
        accel::AccelConfig::paper()
    }
    .nonlinear(design);
    let schedule = PipelineSchedule::for_variant(variant, cfg);
    let tl = if launches > 1 {
        // multi-launch sequence: back-to-back launches of equal batch,
        // cross-launch prefetch per the config (warm steady state)
        Timeline::from_sequence(&schedule, &vec![batch; launches])
    } else {
        Timeline::from_schedule(&schedule, batch)
    };
    if launches > 1 {
        println!(
            "{} {launches} x batch {batch}: {} cycles total — cold launch {} / warm {}",
            variant.name,
            tl.total_cycles,
            schedule.launch_cycles(batch),
            schedule.steady_launch_cycles(batch),
        );
    } else {
        println!(
            "{} batch {batch}: {} cycles ({:.2} ms)",
            variant.name,
            tl.total_cycles,
            schedule.launch_ms(batch)
        );
    }
    for r in Resource::ALL {
        println!(
            "  {:<8} {:>6.1}%  ({} busy cycles)",
            r.name(),
            tl.utilisation(r) * 100.0,
            tl.busy(r)
        );
    }
    if let Some(path) = out {
        std::fs::write(path, tl.to_chrome_trace())?;
        println!("chrome trace written to {path} (open in Perfetto)");
    }
    Ok(())
}

/// Model sharding across cards: print the greedy stage→card partition,
/// the per-bucket cold/warm pipeline costs, optionally export a sharded
/// Chrome trace, and optionally serve the sharded pipeline through the
/// fleet router next to the canonical T/S cards.
#[allow(clippy::too_many_arguments)]
fn cmd_shard(
    variant: &'static SwinVariant,
    budget: usize,
    batch: usize,
    launches: usize,
    out: Option<&str>,
    fleet: bool,
    requests: usize,
    rate: f64,
) -> anyhow::Result<()> {
    use swin_fpga::accel::buffers::BufferPlan;
    use swin_fpga::accel::shard::{ShardPlan, ShardedSchedule};
    use swin_fpga::accel::trace::ShardedTimeline;
    use swin_fpga::server::BUCKET_SIZES;

    let cfg = accel::AccelConfig::paper();
    let whole = BufferPlan::for_variant(variant);
    println!(
        "{} on one card: {} BRAM36 needed vs {budget} available — {}",
        variant.name,
        whole.total_bram36(),
        if whole.fits_device(budget) { "fits" } else { "does not fit" },
    );
    let plan = ShardPlan::for_budget(variant, budget);
    let mut t = swin_fpga::report::Table::new(
        &format!(
            "shard plan: {} over {} card(s) @ {budget} BRAM36/card",
            variant.name,
            plan.cards()
        ),
        &["shard", "stages", "BRAM36", "fits", "weights MB"],
    );
    for (k, s) in plan.shards.iter().enumerate() {
        t.row(&[
            format!("{k}"),
            format!("{}..{}", s.stages.start, s.stages.end),
            format!("{}", s.bram36),
            format!("{}", s.fits),
            format!("{:.1}", s.weight_bytes as f64 / 1e6),
        ]);
    }
    println!("{t}");
    let schedule = ShardedSchedule::for_plan(plan, cfg.clone());
    for k in 0..schedule.cards().saturating_sub(1) {
        println!(
            "link {k}: {} activation bytes/image -> {} cycles (batch {batch})",
            schedule.plan.cut_bytes[k],
            schedule.link_cycles(k, batch),
        );
    }
    let mut costs = swin_fpga::report::Table::new(
        "pipeline launch costs",
        &["batch", "cold ms", "warm ms", "steady FPS"],
    );
    for b in BUCKET_SIZES.iter().rev() {
        let warm_ms = cfg.cycles_to_ms(schedule.steady_launch_cycles(*b));
        costs.row(&[
            format!("{b}"),
            format!("{:.2}", schedule.launch_ms(*b)),
            format!("{warm_ms:.2}"),
            format!("{:.1}", *b as f64 * 1e3 / warm_ms),
        ]);
    }
    println!("{costs}");
    if let Some(path) = out {
        let tl = ShardedTimeline::from_sequence(&schedule, &vec![batch; launches]);
        std::fs::write(path, tl.to_chrome_trace())?;
        println!(
            "sharded chrome trace ({launches} x batch {batch}, {} cycles) written to {path}",
            tl.total_cycles
        );
    }
    if fleet {
        use swin_fpga::server::router::{fleet_percentiles, hetero_ts_fleet, Policy, Router};
        use swin_fpga::server::workload::{classed_arrivals, Arrival};
        use swin_fpga::server::{Engine, ShardedEngine};
        let mut engines = hetero_ts_fleet(&cfg);
        let id = engines.len();
        engines.push(Box::new(ShardedEngine::new(id, variant, cfg.clone(), 0.0)) as Box<dyn Engine>);
        let names: Vec<String> = engines.iter().map(|e| e.name()).collect();
        let mut r = Router::from_engines(engines, Policy::LeastLoaded);
        let arr = classed_arrivals(Arrival::Poisson { rate }, requests, 0.5, 29);
        let comps = r.run_classed(&arr);
        let [p50, p99, inter_p99, batch_p99] = fleet_percentiles(&comps);
        println!(
            "fleet smoke: {requests} requests @ {rate:.0} rps over {} cards — \
             p50 {p50:.1} ms  p99 {p99:.1} ms  interactive p99 {inter_p99:.1} ms  \
             batch p99 {batch_p99:.1} ms",
            names.len(),
        );
        for (name, served) in names.iter().zip(r.served()) {
            println!("  {name:<24} served {served}");
        }
        anyhow::ensure!(
            comps.len() + r.shed_count() as usize == requests,
            "fleet smoke lost requests"
        );
    }
    Ok(())
}

fn cmd_report(artifacts: &std::path::Path) -> anyhow::Result<()> {
    println!("{}", report::table3_submodules());
    println!("{}", report::table4_accelerators());
    println!("{}", report::table5_comparison());
    println!("{}", report::fig11_speedup());
    println!("{}", report::fig12_energy());
    println!("{}", report::sec5a_invalid());
    if artifacts.join("manifest.json").exists() {
        match baseline::live::measure_live_cpu(artifacts, 8) {
            Ok(m) => println!("{m}"),
            Err(e) => println!("(live CPU measurement skipped: {e})"),
        }
    }
    Ok(())
}

fn cmd_selftest(artifacts: &std::path::Path) -> anyhow::Result<()> {
    runtime::selftest(artifacts)?;
    for v in PAPER_VARIANTS {
        let sim = accel::sim::Simulator::new(v, accel::AccelConfig::paper());
        let r = sim.simulate_inference();
        anyhow::ensure!(r.total_cycles > 0, "{} produced zero cycles", v.name);
    }
    println!("selftest OK");
    Ok(())
}
