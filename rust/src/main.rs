//! swin-fpga CLI — leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is not in the vendored registry):
//!
//! ```text
//! swin-fpga simulate [--variant swin-t|swin-s|swin-b|swin-micro] [--images N]
//! swin-fpga serve    [--artifacts DIR] [--requests N] [--rate RPS] [--batch-max N]
//! swin-fpga report   [--artifacts DIR]      # all paper tables/figures
//! swin-fpga selftest [--artifacts DIR]      # runtime + simulator cross-check
//! ```

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use swin_fpga::model::config::{SwinVariant, PAPER_VARIANTS};
use swin_fpga::{accel, baseline, report, runtime, server};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

fn usage() -> &'static str {
    "usage: swin-fpga <simulate|serve|report|selftest> [flags]\n\
     \n\
     simulate  --variant <swin-t|swin-s|swin-b|swin-micro> [--images N]\n\
     serve     [--artifacts DIR] [--requests N] [--rate RPS] [--batch-max N]\n\
     report    [--artifacts DIR]\n\
     selftest  [--artifacts DIR]\n"
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{}", usage());
        return ExitCode::from(2);
    };
    let flags = parse_flags(&args[1..]);
    let artifacts = PathBuf::from(
        flags
            .get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".to_string()),
    );

    let result = match cmd.as_str() {
        "simulate" => {
            let name = flags
                .get("variant")
                .map(String::as_str)
                .unwrap_or("swin-t");
            let Some(variant) = SwinVariant::by_name(name) else {
                eprintln!("unknown variant {name}");
                return ExitCode::from(2);
            };
            let images: usize = flags
                .get("images")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1);
            cmd_simulate(variant, images)
        }
        "serve" => {
            let requests = flags
                .get("requests")
                .and_then(|s| s.parse().ok())
                .unwrap_or(64);
            let rate = flags
                .get("rate")
                .and_then(|s| s.parse().ok())
                .unwrap_or(50.0);
            let batch_max = flags
                .get("batch-max")
                .and_then(|s| s.parse().ok())
                .unwrap_or(8);
            cmd_serve(&artifacts, requests, rate, batch_max)
        }
        "report" => cmd_report(&artifacts),
        "selftest" => cmd_selftest(&artifacts),
        _ => {
            eprint!("{}", usage());
            return ExitCode::from(2);
        }
    };

    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_simulate(variant: &'static SwinVariant, images: usize) -> anyhow::Result<()> {
    let sim = accel::sim::Simulator::new(variant, accel::AccelConfig::paper());
    let r = sim.simulate_inference();
    println!("{}", report::render_sim_result(variant, &r));
    if images > 1 {
        println!(
            "batch of {images}: {:.1} ms total @ {:.1} FPS steady-state",
            images as f64 * r.latency_ms(),
            r.fps()
        );
    }
    Ok(())
}

fn cmd_serve(
    artifacts: &std::path::Path,
    requests: usize,
    rate: f64,
    batch_max: usize,
) -> anyhow::Result<()> {
    let summary = server::run_demo(artifacts, requests, rate, batch_max)?;
    println!("{summary}");
    Ok(())
}

fn cmd_report(artifacts: &std::path::Path) -> anyhow::Result<()> {
    println!("{}", report::table3_submodules());
    println!("{}", report::table4_accelerators());
    println!("{}", report::table5_comparison());
    println!("{}", report::fig11_speedup());
    println!("{}", report::fig12_energy());
    println!("{}", report::sec5a_invalid());
    if artifacts.join("manifest.json").exists() {
        match baseline::live::measure_live_cpu(artifacts, 8) {
            Ok(m) => println!("{m}"),
            Err(e) => println!("(live CPU measurement skipped: {e})"),
        }
    }
    Ok(())
}

fn cmd_selftest(artifacts: &std::path::Path) -> anyhow::Result<()> {
    runtime::selftest(artifacts)?;
    for v in PAPER_VARIANTS {
        let sim = accel::sim::Simulator::new(v, accel::AccelConfig::paper());
        let r = sim.simulate_inference();
        anyhow::ensure!(r.total_cycles > 0, "{} produced zero cycles", v.name);
    }
    println!("selftest OK");
    Ok(())
}
