//! Swin variant configurations — mirror of `python/compile/configs.py`.



/// A Swin Transformer variant (paper §V: Swin-T/S/B; `MICRO` is the
/// full-datapath end-to-end artifact model, see DESIGN.md).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SwinVariant {
    pub name: &'static str,
    pub img_size: usize,
    pub patch_size: usize,
    pub in_chans: usize,
    pub embed_dim: usize,
    pub depths: &'static [usize],
    pub num_heads: &'static [usize],
    /// Window size M (paper: 7 ⇒ M² = 49 rows per window).
    pub window: usize,
    /// FFN expansion ratio M_r (paper: 4).
    pub mlp_ratio: usize,
    pub num_classes: usize,
}

impl SwinVariant {
    pub fn num_stages(&self) -> usize {
        self.depths.len()
    }

    /// Channel count C at stage `s` (doubles per merge).
    pub fn stage_dim(&self, s: usize) -> usize {
        self.embed_dim << s
    }

    /// Feature-map side length at stage `s`.
    pub fn stage_resolution(&self, s: usize) -> usize {
        self.img_size / self.patch_size / (1 << s)
    }

    /// Head dimension (32 everywhere in the paper — why c_o = 32).
    pub fn head_dim(&self) -> usize {
        self.embed_dim / self.num_heads[0]
    }

    pub fn final_dim(&self) -> usize {
        self.stage_dim(self.num_stages() - 1)
    }

    /// Total parameter count of the fused inference model (linear weights
    /// + biases + relative position bias tables).
    pub fn param_count(&self) -> usize {
        let mut p = 0usize;
        let patch_k = self.patch_size * self.patch_size * self.in_chans;
        p += patch_k * self.embed_dim + self.embed_dim;
        let m = self.window;
        for s in 0..self.num_stages() {
            let c = self.stage_dim(s);
            let nh = self.num_heads[s];
            let per_block = c * 3 * c + 3 * c      // qkv
                + c * c + c                         // proj
                + c * self.mlp_ratio * c + self.mlp_ratio * c
                + self.mlp_ratio * c * c + c
                + (2 * m - 1) * (2 * m - 1) * nh;   // rel bias
            p += per_block * self.depths[s];
            if s + 1 < self.num_stages() {
                p += 4 * c * 2 * c + 2 * c; // patch merging
            }
        }
        p += self.final_dim() * self.num_classes + self.num_classes;
        p
    }

    pub fn by_name(name: &str) -> Option<&'static SwinVariant> {
        match name {
            "swin-micro" => Some(&MICRO),
            "swin-t" => Some(&TINY),
            "swin-s" => Some(&SMALL),
            "swin-b" => Some(&BASE),
            _ => None,
        }
    }
}

pub static MICRO: SwinVariant = SwinVariant {
    name: "swin-micro",
    img_size: 56,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 32,
    depths: &[2, 2],
    num_heads: &[1, 2],
    window: 7,
    mlp_ratio: 4,
    num_classes: 10,
};

pub static TINY: SwinVariant = SwinVariant {
    name: "swin-t",
    img_size: 224,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 96,
    depths: &[2, 2, 6, 2],
    num_heads: &[3, 6, 12, 24],
    window: 7,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static SMALL: SwinVariant = SwinVariant {
    name: "swin-s",
    img_size: 224,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 96,
    depths: &[2, 2, 18, 2],
    num_heads: &[3, 6, 12, 24],
    window: 7,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static BASE: SwinVariant = SwinVariant {
    name: "swin-b",
    img_size: 224,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 128,
    depths: &[2, 2, 18, 2],
    num_heads: &[4, 8, 16, 32],
    window: 7,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static PAPER_VARIANTS: [&SwinVariant; 3] = [&TINY, &SMALL, &BASE];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dim_is_32_for_paper_variants() {
        for v in PAPER_VARIANTS {
            assert_eq!(v.head_dim(), 32, "{}", v.name);
            for (s, &nh) in v.num_heads.iter().enumerate() {
                assert_eq!(v.stage_dim(s) / nh, 32);
            }
        }
    }

    #[test]
    fn stage_resolutions_tiny() {
        assert_eq!(
            (0..4).map(|s| TINY.stage_resolution(s)).collect::<Vec<_>>(),
            vec![56, 28, 14, 7]
        );
    }

    #[test]
    fn param_counts_match_published_sizes() {
        // published: Swin-T 28.3M, Swin-S 49.6M, Swin-B 87.8M
        let t = TINY.param_count() as f64 / 1e6;
        let s = SMALL.param_count() as f64 / 1e6;
        let b = BASE.param_count() as f64 / 1e6;
        assert!((t - 28.3).abs() < 1.0, "swin-t params {t}M");
        assert!((s - 49.6).abs() < 1.5, "swin-s params {s}M");
        assert!((b - 87.8).abs() < 2.5, "swin-b params {b}M");
    }

    #[test]
    fn micro_is_consistent() {
        assert_eq!(MICRO.stage_resolution(0), 14);
        assert_eq!(MICRO.stage_resolution(1), 7);
        assert_eq!(MICRO.final_dim(), 64);
    }

    #[test]
    fn by_name_roundtrip() {
        for v in PAPER_VARIANTS {
            assert_eq!(SwinVariant::by_name(v.name).unwrap().name, v.name);
        }
        assert!(SwinVariant::by_name("nope").is_none());
    }
}
