//! Swin variant configurations — mirror of `python/compile/configs.py`.



/// A Swin Transformer variant (paper §V: Swin-T/S/B; `MICRO` is the
/// full-datapath end-to-end artifact model, see DESIGN.md).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SwinVariant {
    pub name: &'static str,
    pub img_size: usize,
    pub patch_size: usize,
    pub in_chans: usize,
    pub embed_dim: usize,
    pub depths: &'static [usize],
    pub num_heads: &'static [usize],
    /// Window size M (paper: 7 ⇒ M² = 49 rows per window).
    pub window: usize,
    /// FFN expansion ratio M_r (paper: 4).
    pub mlp_ratio: usize,
    pub num_classes: usize,
}

impl SwinVariant {
    pub fn num_stages(&self) -> usize {
        self.depths.len()
    }

    /// Channel count C at stage `s` (doubles per merge).
    pub fn stage_dim(&self, s: usize) -> usize {
        self.embed_dim << s
    }

    /// Feature-map side length at stage `s`.
    pub fn stage_resolution(&self, s: usize) -> usize {
        self.img_size / self.patch_size / (1 << s)
    }

    /// Head dimension (32 everywhere in the paper — why c_o = 32).
    pub fn head_dim(&self) -> usize {
        self.embed_dim / self.num_heads[0]
    }

    pub fn final_dim(&self) -> usize {
        self.stage_dim(self.num_stages() - 1)
    }

    /// Total parameter count of the fused inference model (linear weights
    /// + biases + relative position bias tables).
    pub fn param_count(&self) -> usize {
        let mut p = 0usize;
        let patch_k = self.patch_size * self.patch_size * self.in_chans;
        p += patch_k * self.embed_dim + self.embed_dim;
        let m = self.window;
        for s in 0..self.num_stages() {
            let c = self.stage_dim(s);
            let nh = self.num_heads[s];
            let per_block = c * 3 * c + 3 * c      // qkv
                + c * c + c                         // proj
                + c * self.mlp_ratio * c + self.mlp_ratio * c
                + self.mlp_ratio * c * c + c
                + (2 * m - 1) * (2 * m - 1) * nh;   // rel bias
            p += per_block * self.depths[s];
            if s + 1 < self.num_stages() {
                p += 4 * c * 2 * c + 2 * c; // patch merging
            }
        }
        p += self.final_dim() * self.num_classes + self.num_classes;
        p
    }

    /// Look a variant up in [`REGISTRY`] by name, case-insensitively.
    /// Data-driven so newly registered variants can never silently miss
    /// the lookup (the old exact-match whitelist did exactly that).
    pub fn by_name(name: &str) -> Option<&'static SwinVariant> {
        REGISTRY
            .iter()
            .find(|v| v.name.eq_ignore_ascii_case(name))
            .copied()
    }
}

pub static MICRO: SwinVariant = SwinVariant {
    name: "swin-micro",
    img_size: 56,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 32,
    depths: &[2, 2],
    num_heads: &[1, 2],
    window: 7,
    mlp_ratio: 4,
    num_classes: 10,
};

pub static TINY: SwinVariant = SwinVariant {
    name: "swin-t",
    img_size: 224,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 96,
    depths: &[2, 2, 6, 2],
    num_heads: &[3, 6, 12, 24],
    window: 7,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static SMALL: SwinVariant = SwinVariant {
    name: "swin-s",
    img_size: 224,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 96,
    depths: &[2, 2, 18, 2],
    num_heads: &[3, 6, 12, 24],
    window: 7,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static BASE: SwinVariant = SwinVariant {
    name: "swin-b",
    img_size: 224,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 128,
    depths: &[2, 2, 18, 2],
    num_heads: &[4, 8, 16, 32],
    window: 7,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static LARGE: SwinVariant = SwinVariant {
    name: "swin-l",
    img_size: 224,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 192,
    depths: &[2, 2, 18, 2],
    num_heads: &[6, 12, 24, 48],
    window: 7,
    mlp_ratio: 4,
    num_classes: 1000,
};

// 384-input variants (the published high-resolution checkpoints): same
// depths/dims as the 224 models, 12×12 windows so four windows still
// tile the final 12×12 feature map.
pub static TINY_384: SwinVariant = SwinVariant {
    name: "swin-t-384",
    img_size: 384,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 96,
    depths: &[2, 2, 6, 2],
    num_heads: &[3, 6, 12, 24],
    window: 12,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static BASE_384: SwinVariant = SwinVariant {
    name: "swin-b-384",
    img_size: 384,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 128,
    depths: &[2, 2, 18, 2],
    num_heads: &[4, 8, 16, 32],
    window: 12,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static LARGE_384: SwinVariant = SwinVariant {
    name: "swin-l-384",
    img_size: 384,
    patch_size: 4,
    in_chans: 3,
    embed_dim: 192,
    depths: &[2, 2, 18, 2],
    num_heads: &[6, 12, 24, 48],
    window: 12,
    mlp_ratio: 4,
    num_classes: 1000,
};

pub static PAPER_VARIANTS: [&SwinVariant; 3] = [&TINY, &SMALL, &BASE];

/// Every registered variant — the single source of truth for
/// [`SwinVariant::by_name`] and the CLI's variant listings.
pub static REGISTRY: [&SwinVariant; 8] = [
    &MICRO,
    &TINY,
    &SMALL,
    &BASE,
    &LARGE,
    &TINY_384,
    &BASE_384,
    &LARGE_384,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dim_is_32_for_paper_variants() {
        for v in PAPER_VARIANTS {
            assert_eq!(v.head_dim(), 32, "{}", v.name);
            for (s, &nh) in v.num_heads.iter().enumerate() {
                assert_eq!(v.stage_dim(s) / nh, 32);
            }
        }
    }

    #[test]
    fn stage_resolutions_tiny() {
        assert_eq!(
            (0..4).map(|s| TINY.stage_resolution(s)).collect::<Vec<_>>(),
            vec![56, 28, 14, 7]
        );
    }

    #[test]
    fn param_counts_match_published_sizes() {
        // published: Swin-T 28.3M, Swin-S 49.6M, Swin-B 87.8M
        let t = TINY.param_count() as f64 / 1e6;
        let s = SMALL.param_count() as f64 / 1e6;
        let b = BASE.param_count() as f64 / 1e6;
        assert!((t - 28.3).abs() < 1.0, "swin-t params {t}M");
        assert!((s - 49.6).abs() < 1.5, "swin-s params {s}M");
        assert!((b - 87.8).abs() < 2.5, "swin-b params {b}M");
        // published: Swin-L 197M; 384 checkpoints differ from the 224
        // models only in the relative-position-bias tables (23² vs 13²
        // entries per head), so the counts sit just above them
        let l = LARGE.param_count() as f64 / 1e6;
        assert!((l - 196.5).abs() < 2.0, "swin-l params {l}M");
        let t384 = TINY_384.param_count() as f64 / 1e6;
        let b384 = BASE_384.param_count() as f64 / 1e6;
        let l384 = LARGE_384.param_count() as f64 / 1e6;
        assert!((t384 - 28.3).abs() < 1.0, "swin-t-384 params {t384}M");
        assert!((b384 - 87.9).abs() < 2.5, "swin-b-384 params {b384}M");
        assert!((l384 - 196.7).abs() < 2.0, "swin-l-384 params {l384}M");
        assert!(t384 > t && b384 > b && l384 > l);
    }

    #[test]
    fn registry_variants_are_consistent() {
        for v in REGISTRY {
            // head_dim = 32 everywhere (the c_o = 32 design point) and
            // the window tiles every stage's feature map exactly
            for (s, &nh) in v.num_heads.iter().enumerate() {
                assert_eq!(v.stage_dim(s) / nh, 32, "{} stage {s}", v.name);
            }
            for s in 0..v.num_stages() {
                assert_eq!(
                    v.stage_resolution(s) % v.window,
                    0,
                    "{} stage {s}: window {} does not tile {}",
                    v.name,
                    v.window,
                    v.stage_resolution(s)
                );
            }
        }
        // 384 inputs halve to a 12×12 final map — one 12×12 window
        assert_eq!(LARGE_384.stage_resolution(3), 12);
        assert_eq!(LARGE_384.window, 12);
    }

    #[test]
    fn micro_is_consistent() {
        assert_eq!(MICRO.stage_resolution(0), 14);
        assert_eq!(MICRO.stage_resolution(1), 7);
        assert_eq!(MICRO.final_dim(), 64);
    }

    #[test]
    fn by_name_roundtrip() {
        for v in REGISTRY {
            assert_eq!(SwinVariant::by_name(v.name).unwrap().name, v.name);
            // case-insensitive lookup resolves to the same variant
            let upper = v.name.to_ascii_uppercase();
            assert_eq!(SwinVariant::by_name(&upper).unwrap().name, v.name);
        }
        assert!(SwinVariant::by_name("nope").is_none());
    }
}
