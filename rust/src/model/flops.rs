//! Computational-complexity closed forms (paper Eqs. 13–17).
//!
//! The paper analyses the Swin block's linear work and the fraction of
//! "invalid computation" introduced by zero-padding Kᵀ to the MMU's
//! c_o = 32 output-tile width. These are the analytical counterparts of
//! the exact per-op counts in [`super::graph`]; the `sec5a_invalid_compute`
//! bench prints both.

use super::config::SwinVariant;
use super::graph::TILE_N;

/// Eq. 13: Ω(W-MSA) = 4hwC² + 2M²hwC  (MACs, one block).
pub fn omega_wmsa(h: usize, w: usize, c: usize, m: usize) -> u64 {
    (4 * h * w * c * c + 2 * m * m * h * w * c) as u64
}

/// Eq. 14: Ω(FFN) = 2·M_r·hwC² (= 8hwC² at M_r = 4).
pub fn omega_ffn(h: usize, w: usize, c: usize, mr: usize) -> u64 {
    (2 * mr * h * w * c * c) as u64
}

/// Eq. 15: Ω(q·kᵀ) = M²hwC.
pub fn omega_qkt(h: usize, w: usize, c: usize, m: usize) -> u64 {
    (m * m * h * w * c) as u64
}

/// Eq. 16: Ω(q·k_eᵀ) = 2c_o·hwC — the Q·Kᵀ work after the Kᵀ matrix is
/// zero-expanded from M² = 49 to 2c_o = 64 columns.
pub fn omega_qkt_expanded(h: usize, w: usize, c: usize) -> u64 {
    (2 * TILE_N * h * w * c) as u64
}

/// Eq. 17: U — the fraction of invalid computation in one Swin block.
///
/// U = (2c_o·hwC − M²hwC) / (12hwC² + 2M²hwC)
pub fn invalid_fraction_block(c: usize, m: usize) -> f64 {
    invalid_fraction_block_with_co(c, m, TILE_N)
}

/// Eq. 17 generalised to an arbitrary output-tile width c_o (the
/// `design_space` ablation): the expanded Kᵀ has ⌈M²/c_o⌉·c_o columns.
pub fn invalid_fraction_block_with_co(c: usize, m: usize, co: usize) -> f64 {
    let m2 = m * m;
    let expanded = m2.div_ceil(co) * co;
    let num = (expanded - m2) as f64; // × hwC
    let den = 12.0 * c as f64 + 2.0 * m2 as f64; // × hwC
    num / den
}

/// Eq. 17 aggregated over a whole variant, weighting each stage by its
/// depth and resolution (the paper quotes the resulting U ≈ 1.2%).
pub fn invalid_fraction_variant(v: &SwinVariant) -> f64 {
    let m = v.window;
    let mut invalid = 0f64;
    let mut total = 0f64;
    for s in 0..v.num_stages() {
        let c = v.stage_dim(s);
        let r = v.stage_resolution(s);
        let d = v.depths[s] as f64;
        let hw = (r * r) as f64;
        let block_linear = (omega_wmsa(r, r, c, m) - omega_qkt(r, r, c, m)
            + omega_qkt_expanded(r, r, c)
            + omega_ffn(r, r, c, v.mlp_ratio)) as f64;
        let block_invalid = (omega_qkt_expanded(r, r, c) - omega_qkt(r, r, c, m)) as f64;
        invalid += d * block_invalid;
        total += d * block_linear;
        let _ = hw;
    }
    invalid / total
}

/// Total block MACs for a variant from the closed forms (patch embed,
/// merging and head excluded — matches the paper's Eq. 13/14 scope).
pub fn block_macs_variant(v: &SwinVariant) -> u64 {
    let m = v.window;
    let mut total = 0u64;
    for s in 0..v.num_stages() {
        let c = v.stage_dim(s);
        let r = v.stage_resolution(s);
        total += v.depths[s] as u64
            * (omega_wmsa(r, r, c, m) + omega_ffn(r, r, c, v.mlp_ratio));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{BASE, SMALL, TINY};
    use crate::model::graph::WorkloadGraph;

    #[test]
    fn eq17_block_closed_form_gives_paper_value() {
        // paper §V.A computes Eq. 17 at the base channel count C and
        // quotes U = 1.2%: for C = 96 that is exactly what falls out
        let u96 = invalid_fraction_block(96, 7);
        assert!((u96 - 0.012).abs() < 0.0005, "C=96: U = {u96:.4}");
        // Swin-B's C = 128 gives ~0.92% (the paper rounds all three to
        // "1.2%"; our number is the exact evaluation)
        let u128 = invalid_fraction_block(128, 7);
        assert!((u128 - 0.0092).abs() < 0.0005, "C=128: U = {u128:.4}");
    }

    #[test]
    fn aggregate_u_is_smaller_than_blockwise() {
        // later stages double C, so their invalid share shrinks — the
        // network-wide aggregate sits below the paper's stage-0 figure
        for v in [&TINY, &SMALL, &BASE] {
            let u = invalid_fraction_variant(v);
            assert!(
                u > 0.002 && u < 0.013,
                "{}: aggregate U = {:.4}",
                v.name,
                u
            );
        }
    }

    #[test]
    fn closed_form_close_to_exact_graph() {
        for v in [&TINY, &SMALL, &BASE] {
            let graph = WorkloadGraph::build(v);
            let cf = block_macs_variant(v) as f64;
            let exact = graph.total_macs() as f64;
            // closed form excludes patch embed/merge/head (~3% of total)
            let ratio = cf / exact;
            assert!(
                ratio > 0.90 && ratio < 1.01,
                "{}: closed/exact = {ratio}",
                v.name
            );
        }
    }

    #[test]
    fn wmsa_dominated_by_projections() {
        // 4hwC² (projections) > 2M²hwC (attention) for C > M²/2
        let (h, w, c, m) = (56, 56, 96, 7);
        assert!(4 * h * w * c * c > 2 * m * m * h * w * c);
    }

    #[test]
    fn paper_gops_figures_reproduced() {
        // Table V: GOPS = 2 × MACs × FPS. With the paper's FPS this
        // implies MAC totals of ~4.5/8.7/15.4 G for T/S/B.
        let t = WorkloadGraph::build(&TINY).total_macs() as f64 / 1e9;
        let s = WorkloadGraph::build(&SMALL).total_macs() as f64 / 1e9;
        let b = WorkloadGraph::build(&BASE).total_macs() as f64 / 1e9;
        assert!((2.0 * t * 48.1 - 431.2).abs() / 431.2 < 0.06, "T: {t}");
        assert!((2.0 * s * 25.0 - 436.4).abs() / 436.4 < 0.06, "S: {s}");
        assert!((2.0 * b * 13.1 - 403.5).abs() / 403.5 < 0.06, "B: {b}");
    }
}
