//! BN → linear fusion algebra (paper §III.A, Eqs. 2–4) — Rust golden check.
//!
//! The production fusion runs at export time in `python/compile/fusion.py`;
//! this module re-states the algebra over plain `f32` buffers so the Rust
//! test suite can independently verify Eqs. 2–4 (and so downstream users
//! can fuse their own checkpoints without Python).

/// Frozen BN parameters for one channel dimension.
#[derive(Debug, Clone)]
pub struct BnParams {
    pub gamma: Vec<f32>,
    pub beta: Vec<f32>,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

pub const EPS: f32 = 1e-5;

impl BnParams {
    pub fn dim(&self) -> usize {
        self.gamma.len()
    }

    /// Per-channel scale s = γ / √(σ² + ε).
    pub fn scale(&self) -> Vec<f32> {
        self.gamma
            .iter()
            .zip(&self.var)
            .map(|(g, v)| g / (v + EPS).sqrt())
            .collect()
    }

    /// Apply BN (inference semantics) to a row-major (rows × dim) matrix.
    pub fn apply(&self, x: &[f32], dim: usize) -> Vec<f32> {
        let s = self.scale();
        x.chunks_exact(dim)
            .flat_map(|row| {
                row.iter()
                    .enumerate()
                    .map(|(j, &v)| (v - self.mean[j]) * s[j] + self.beta[j])
                    .collect::<Vec<_>>()
            })
            .collect()
    }
}

/// Pre-fusion (Eq. 3/4): `y = BN(x) W + b` → `y = x W' + b'` with
/// `W' = diag(s)·W`, `b' = b + (β − μ·s)·W`. `w` is (din × dout) row-major.
pub fn pre_fuse(bn: &BnParams, w: &[f32], b: &[f32], din: usize, dout: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(bn.dim(), din);
    assert_eq!(w.len(), din * dout);
    assert_eq!(b.len(), dout);
    let s = bn.scale();
    let mut w2 = vec![0f32; din * dout];
    for i in 0..din {
        for j in 0..dout {
            w2[i * dout + j] = w[i * dout + j] * s[i];
        }
    }
    let mut b2 = b.to_vec();
    for i in 0..din {
        let t = bn.beta[i] - bn.mean[i] * s[i];
        for j in 0..dout {
            b2[j] += t * w[i * dout + j];
        }
    }
    (w2, b2)
}

/// Post-fusion (Eq. 2 viewed as a 1×1 conv after the linear):
/// `y = BN(x W + b)` → `W' = W·diag(s)`, `b' = (b − μ)·s + β`.
pub fn post_fuse(bn: &BnParams, w: &[f32], b: &[f32], din: usize, dout: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(bn.dim(), dout);
    let s = bn.scale();
    let mut w2 = vec![0f32; din * dout];
    for i in 0..din {
        for j in 0..dout {
            w2[i * dout + j] = w[i * dout + j] * s[j];
        }
    }
    let b2: Vec<f32> = (0..dout)
        .map(|j| (b[j] - bn.mean[j]) * s[j] + bn.beta[j])
        .collect();
    (w2, b2)
}

#[cfg(test)]
fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], rows: usize, din: usize, dout: usize) -> Vec<f32> {
    let mut y = vec![0f32; rows * dout];
    for r in 0..rows {
        for j in 0..dout {
            let mut acc = b[j];
            for i in 0..din {
                acc += x[r * din + i] * w[i * dout + j];
            }
            y[r * dout + j] = acc;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bn3() -> BnParams {
        BnParams {
            gamma: vec![1.5, 0.5, 2.0],
            beta: vec![0.1, -0.2, 0.0],
            mean: vec![0.3, -0.4, 1.0],
            var: vec![2.0, 0.5, 1.0],
        }
    }

    fn toy() -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        // x: 4×3, w: 3×2, b: 2
        let x = vec![
            0.5, -1.0, 2.0, /**/ 1.5, 0.0, -0.5, /**/ -2.0, 1.0, 0.25, /**/ 0.0, 0.75, -1.5,
        ];
        let w = vec![0.2, -0.3, 0.5, 0.7, -0.1, 0.4];
        let b = vec![0.05, -0.1];
        (x, w, b)
    }

    #[test]
    fn pre_fuse_matches_explicit_bn_then_linear() {
        let bn = bn3();
        let (x, w, b) = toy();
        let want = matmul_bias(&bn.apply(&x, 3), &w, &b, 4, 3, 2);
        let (w2, b2) = pre_fuse(&bn, &w, &b, 3, 2);
        let got = matmul_bias(&x, &w2, &b2, 4, 3, 2);
        for (g, t) in got.iter().zip(&want) {
            assert!((g - t).abs() < 1e-5, "{g} vs {t}");
        }
    }

    #[test]
    fn post_fuse_matches_linear_then_bn() {
        let bn = BnParams {
            gamma: vec![1.2, 0.8],
            beta: vec![-0.1, 0.3],
            mean: vec![0.5, -0.25],
            var: vec![1.5, 0.75],
        };
        let (x, w, b) = toy();
        let lin = matmul_bias(&x, &w, &b, 4, 3, 2);
        let want = bn.apply(&lin, 2);
        let (w2, b2) = post_fuse(&bn, &w, &b, 3, 2);
        let got = matmul_bias(&x, &w2, &b2, 4, 3, 2);
        for (g, t) in got.iter().zip(&want) {
            assert!((g - t).abs() < 1e-5, "{g} vs {t}");
        }
    }

    #[test]
    fn identity_bn_is_noop() {
        let bn = BnParams {
            gamma: vec![1.0; 3],
            beta: vec![0.0; 3],
            mean: vec![0.0; 3],
            var: vec![1.0 - EPS; 3],
        };
        let (_, w, b) = toy();
        let (w2, b2) = pre_fuse(&bn, &w, &b, 3, 2);
        for (a, c) in w.iter().zip(&w2) {
            assert!((a - c).abs() < 1e-6);
        }
        for (a, c) in b.iter().zip(&b2) {
            assert!((a - c).abs() < 1e-6);
        }
    }

    #[test]
    fn fusion_composes() {
        // BN → linear → BN fully folds into one (w, b): pre then post
        let bn_in = bn3();
        let bn_out = BnParams {
            gamma: vec![0.9, 1.1],
            beta: vec![0.2, -0.3],
            mean: vec![0.1, 0.0],
            var: vec![1.0, 2.0],
        };
        let (x, w, b) = toy();
        let want = bn_out.apply(
            &matmul_bias(&bn_in.apply(&x, 3), &w, &b, 4, 3, 2),
            2,
        );
        let (w1, b1) = pre_fuse(&bn_in, &w, &b, 3, 2);
        let (w2, b2) = post_fuse(&bn_out, &w1, &b1, 3, 2);
        let got = matmul_bias(&x, &w2, &b2, 4, 3, 2);
        for (g, t) in got.iter().zip(&want) {
            assert!((g - t).abs() < 1e-5);
        }
    }
}
