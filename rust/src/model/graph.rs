//! Workload IR: the exact sequence of accelerator operations for one
//! image through a Swin variant.
//!
//! Built once per variant, consumed by the cycle simulator
//! ([`crate::accel::sim`]), the MAC counter ([`super::flops`]) and the
//! memory-traffic model. Every GEMM records both its *logical* shape and
//! the MMU-padded shape (rows → multiples of M²=49, K/N → multiples of
//! c_i/c_o = 32) so invalid computation (paper §V.A) falls out directly.



use super::config::SwinVariant;

/// MMU tile geometry (paper §IV.B): 32 PEs × 49 multipliers.
pub const TILE_M: usize = 49;
pub const TILE_K: usize = 32;
pub const TILE_N: usize = 32;

/// What a GEMM is doing, for reporting and per-phase accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmKind {
    PatchEmbed,
    Qkv,
    /// Q·Kᵀ similarity — the zero-padded-Kᵀ exception (paper §V.A).
    Scores,
    /// attention-weights · V.
    AttnV,
    Proj,
    Mlp1,
    Mlp2,
    PatchMerge,
    Head,
}

/// One accelerator operation.
#[derive(Debug, Clone)]
pub enum OpKind {
    /// `rows×k @ k×n` on the MMU; `batch` independent instances
    /// (windows × heads for attention GEMMs).
    Gemm {
        kind: GemmKind,
        batch: usize,
        rows: usize,
        k: usize,
        n: usize,
    },
    /// SCU: `rows` independent softmax rows of `width` lanes.
    Softmax { rows: usize, width: usize },
    /// GCU: elementwise GELU over `elems` values.
    Gelu { elems: usize },
    /// Shortcut addition over `elems` values (absorbed by the MMU
    /// accumulation module per paper §IV.A — zero extra cycles, tracked
    /// for completeness).
    Add { elems: usize },
}

/// An op plus its place in the network (stage/block) for reporting.
#[derive(Debug, Clone)]
pub struct LayerOp {
    pub stage: usize,
    pub block: usize,
    pub op: OpKind,
    /// Weight bytes this op streams from external memory (16-bit fixed).
    pub weight_bytes: usize,
    /// Activation bytes read + written from/to external memory.
    pub activation_bytes: usize,
}

fn pad_to(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

impl OpKind {
    /// Logical multiply-accumulate count (no padding).
    pub fn macs(&self) -> u64 {
        match *self {
            OpKind::Gemm {
                batch, rows, k, n, ..
            } => (batch * rows * k * n) as u64,
            _ => 0,
        }
    }

    /// MACs the MMU actually performs, after zero-padding each operand to
    /// tile alignment (the "invalid computations" of paper §V.A are the
    /// difference vs [`Self::macs`]).
    pub fn padded_macs(&self) -> u64 {
        match *self {
            OpKind::Gemm {
                batch, rows, k, n, ..
            } => {
                (batch * pad_to(rows, TILE_M) * pad_to(k, TILE_K) * pad_to(n, TILE_N))
                    as u64
            }
            _ => 0,
        }
    }

    /// Nonlinear element count (softmax lanes / GELU elements).
    pub fn nonlinear_elems(&self) -> u64 {
        match *self {
            OpKind::Softmax { rows, width } => (rows * width) as u64,
            OpKind::Gelu { elems } => elems as u64,
            _ => 0,
        }
    }
}

/// The full per-image workload of a variant.
#[derive(Debug, Clone)]
pub struct WorkloadGraph {
    pub variant: &'static str,
    pub ops: Vec<LayerOp>,
}

impl WorkloadGraph {
    /// Build the exact op list for one inference (mirrors
    /// `model.forward_fixed`'s structure).
    pub fn build(v: &SwinVariant) -> Self {
        let mut ops = Vec::new();
        let m = v.window;
        let m2 = m * m;
        let act = |elems: usize| 2 * elems; // int16 bytes

        // --- Patch embedding (conv-as-matmul, Fig. 5) -------------------
        let hp = v.img_size / v.patch_size;
        let patch_k = v.patch_size * v.patch_size * v.in_chans;
        let tokens0 = hp * hp;
        ops.push(LayerOp {
            stage: 0,
            block: usize::MAX,
            op: OpKind::Gemm {
                kind: GemmKind::PatchEmbed,
                batch: 1,
                rows: tokens0,
                k: patch_k,
                n: v.embed_dim,
            },
            weight_bytes: 2 * patch_k * v.embed_dim,
            // input image (raw) + output tokens
            activation_bytes: act(v.img_size * v.img_size * v.in_chans)
                + act(tokens0 * v.embed_dim),
        });

        // --- Stages ------------------------------------------------------
        for s in 0..v.num_stages() {
            let c = v.stage_dim(s);
            let res = v.stage_resolution(s);
            let tokens = res * res;
            let nh = v.num_heads[s];
            let dh = c / nh;
            let nw = (res / m) * (res / m);
            for b in 0..v.depths[s] {
                let io = act(tokens * c); // block in or out feature map
                // QKV projection
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Gemm {
                        kind: GemmKind::Qkv,
                        batch: 1,
                        rows: tokens,
                        k: c,
                        n: 3 * c,
                    },
                    weight_bytes: 2 * c * 3 * c,
                    // block input is read once from external memory; the
                    // QKV outputs stay in the ILB (paper §IV.A dataflow)
                    activation_bytes: io,
                });
                // Q·Kᵀ — per window, per head; N = M² = 49 is NOT a
                // multiple of c_o=32: the padded-Kᵀ case.
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Gemm {
                        kind: GemmKind::Scores,
                        batch: nw * nh,
                        rows: m2,
                        k: dh,
                        n: m2,
                    },
                    weight_bytes: 0, // K comes from the ILB, not ext. mem
                    activation_bytes: 0,
                });
                // Softmax over each score row
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Softmax {
                        rows: nw * nh * m2,
                        width: m2,
                    },
                    weight_bytes: 0,
                    activation_bytes: 0,
                });
                // attn · V
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Gemm {
                        kind: GemmKind::AttnV,
                        batch: nw * nh,
                        rows: m2,
                        k: m2,
                        n: dh,
                    },
                    weight_bytes: 0,
                    activation_bytes: 0,
                });
                // output projection (+ shortcut add in accumulation)
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Gemm {
                        kind: GemmKind::Proj,
                        batch: 1,
                        rows: tokens,
                        k: c,
                        n: c,
                    },
                    weight_bytes: 2 * c * c,
                    // shortcut operand re-read through the FIB
                    activation_bytes: io,
                });
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Add { elems: tokens * c },
                    weight_bytes: 0,
                    activation_bytes: 0,
                });
                // FFN
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Gemm {
                        kind: GemmKind::Mlp1,
                        batch: 1,
                        rows: tokens,
                        k: c,
                        n: v.mlp_ratio * c,
                    },
                    weight_bytes: 2 * c * v.mlp_ratio * c,
                    // FFN input is ILB-resident after the shortcut; the
                    // hidden activations stream row-wise into the GCU
                    activation_bytes: 0,
                });
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Gelu {
                        elems: tokens * v.mlp_ratio * c,
                    },
                    weight_bytes: 0,
                    activation_bytes: 0,
                });
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Gemm {
                        kind: GemmKind::Mlp2,
                        batch: 1,
                        rows: tokens,
                        k: v.mlp_ratio * c,
                        n: c,
                    },
                    weight_bytes: 2 * v.mlp_ratio * c * c,
                    // MWU writes the block output to external memory
                    activation_bytes: io,
                });
                ops.push(LayerOp {
                    stage: s,
                    block: b,
                    op: OpKind::Add { elems: tokens * c },
                    weight_bytes: 0,
                    activation_bytes: 0,
                });
            }
            // Patch merging
            if s + 1 < v.num_stages() {
                let out_tokens = tokens / 4;
                ops.push(LayerOp {
                    stage: s,
                    block: usize::MAX,
                    op: OpKind::Gemm {
                        kind: GemmKind::PatchMerge,
                        batch: 1,
                        rows: out_tokens,
                        k: 4 * c,
                        n: 2 * c,
                    },
                    weight_bytes: 2 * 4 * c * 2 * c,
                    activation_bytes: act(tokens * c) + act(out_tokens * 2 * c),
                });
            }
        }

        // --- Head (GAP is a reduction in the output buffer; then matmul)
        let df = v.final_dim();
        ops.push(LayerOp {
            stage: v.num_stages() - 1,
            block: usize::MAX,
            op: OpKind::Gemm {
                kind: GemmKind::Head,
                batch: 1,
                rows: 1,
                k: df,
                n: v.num_classes,
            },
            weight_bytes: 2 * df * v.num_classes,
            activation_bytes: act(df) + act(v.num_classes),
        });

        WorkloadGraph {
            variant: v.name,
            ops,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.op.macs()).sum()
    }

    pub fn total_padded_macs(&self) -> u64 {
        self.ops.iter().map(|o| o.op.padded_macs()).sum()
    }

    /// Fraction of MMU work that is zero-padding (paper §V.A's U, but
    /// measured over the whole network rather than Eq. 17's block-only
    /// closed form).
    pub fn invalid_fraction(&self) -> f64 {
        let real = self.total_macs() as f64;
        let padded = self.total_padded_macs() as f64;
        (padded - real) / padded
    }

    pub fn total_weight_bytes(&self) -> usize {
        self.ops.iter().map(|o| o.weight_bytes).sum()
    }

    pub fn total_activation_bytes(&self) -> usize {
        self.ops.iter().map(|o| o.activation_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{MICRO, SMALL, TINY};

    #[test]
    fn tiny_macs_match_published_flops() {
        // Swin-T is commonly reported at ~4.5 GFLOPs (= GMACs in the ViT
        // convention the paper uses for its GOPS figures)
        let g = WorkloadGraph::build(&TINY);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((gmacs - 4.5).abs() < 0.3, "swin-t {gmacs} GMACs");
    }

    #[test]
    fn small_macs() {
        let g = WorkloadGraph::build(&SMALL);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((gmacs - 8.7).abs() < 0.5, "swin-s {gmacs} GMACs");
    }

    #[test]
    fn padding_overhead_small_and_positive() {
        for v in [&TINY, &SMALL] {
            let g = WorkloadGraph::build(v);
            let u = g.invalid_fraction();
            assert!(u > 0.0 && u < 0.05, "{}: U={u}", v.name);
        }
    }

    #[test]
    fn weight_bytes_match_param_count_sans_biases() {
        // graph counts linear weights only; biases/rel-bias are small
        let g = WorkloadGraph::build(&TINY);
        let wb = g.total_weight_bytes() as f64;
        let pb = (TINY.param_count() * 2) as f64;
        assert!((wb - pb).abs() / pb < 0.02, "wb={wb} pb={pb}");
    }

    #[test]
    fn op_counts_scale_with_depth() {
        let gt = WorkloadGraph::build(&TINY).ops.len();
        let gs = WorkloadGraph::build(&SMALL).ops.len();
        assert!(gs > gt);
    }

    #[test]
    fn micro_graph_structure() {
        let g = WorkloadGraph::build(&MICRO);
        // 1 patch embed + 4 blocks × 10 ops + 1 merge + 1 head
        assert_eq!(g.ops.len(), 1 + 4 * 10 + 1 + 1);
        assert!(g.total_macs() > 0);
    }

    #[test]
    fn scores_gemm_has_padded_n() {
        let g = WorkloadGraph::build(&TINY);
        let scores: Vec<_> = g
            .ops
            .iter()
            .filter_map(|o| match o.op {
                OpKind::Gemm {
                    kind: GemmKind::Scores,
                    rows,
                    k,
                    n,
                    batch,
                } => Some((batch, rows, k, n)),
                _ => None,
            })
            .collect();
        assert!(!scores.is_empty());
        for (_, rows, k, n) in scores {
            assert_eq!(rows, 49);
            assert_eq!(k, 32);
            assert_eq!(n, 49); // pads to 64 in the MMU
        }
    }
}
