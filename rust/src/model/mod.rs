//! Swin Transformer model substrate (workload description, not weights
//! training): variant configs, the per-layer GEMM/nonlinear op graph the
//! accelerator executes, MAC counting (paper Eqs. 13–17), BN→linear
//! fusion algebra (Eqs. 2–4) and quantised-weight loading for the
//! simulator's functional datapath.

pub mod config;
pub mod flops;
pub mod fusion;
pub mod graph;
pub mod quantize;
pub mod weights;

pub use config::{SwinVariant, BASE, MICRO, SMALL, TINY};
pub use graph::{LayerOp, OpKind, WorkloadGraph};
