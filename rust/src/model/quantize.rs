//! Quantisation analysis (paper §V.C "Quantification Methods").
//!
//! The paper asserts 16-bit fixed point carries Swin "without any
//! noticeable loss in precision" but reports no numbers. This module
//! quantifies that claim: SQNR of each Q-format over realistic value
//! distributions, per-format dynamic-range coverage, and the end-to-end
//! logit error the `quantization` bench reports (the `quant_sweep`
//! python experiment does the accuracy-side counterpart).

use crate::fixed::{dequantize, quantize, I16_MAX, I16_MIN};

/// Signal-to-quantisation-noise ratio (dB) of quantising `xs` at `frac`
/// fractional bits (Q-format with int16 saturation).
pub fn sqnr_db(xs: &[f32], frac: u32) -> f64 {
    let mut sig = 0f64;
    let mut noise = 0f64;
    for &x in xs {
        let q = dequantize(quantize(x, frac), frac);
        sig += (x as f64) * (x as f64);
        let e = (x - q) as f64;
        noise += e * e;
    }
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

/// Fraction of values that saturate at `frac` bits.
pub fn saturation_rate(xs: &[f32], frac: u32) -> f64 {
    let sat = xs
        .iter()
        .filter(|&&x| {
            let q = quantize(x, frac);
            q == I16_MAX || q == I16_MIN
        })
        .count();
    sat as f64 / xs.len().max(1) as f64
}

/// Pick the best frac bits for a tensor: highest SQNR with saturation
/// below `max_sat` (the per-tensor calibration a deployment would run).
pub fn calibrate_frac(xs: &[f32], max_sat: f64) -> (u32, f64) {
    let mut best = (0u32, f64::MIN);
    for frac in 4..=14 {
        if saturation_rate(xs, frac) > max_sat {
            continue;
        }
        let s = sqnr_db(xs, frac);
        if s > best.1 {
            best = (frac, s);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn gaussian(sigma: f32, n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(n, sigma)
    }

    #[test]
    fn sqnr_improves_with_frac_bits_until_saturation() {
        let xs = gaussian(1.0, 20_000, 1);
        let s8 = sqnr_db(&xs, 8);
        let s12 = sqnr_db(&xs, 12);
        assert!(s12 > s8 + 20.0, "s8={s8} s12={s12}");
        // ~6 dB per bit in the unsaturated regime
        assert!((s12 - s8 - 24.0).abs() < 3.0, "delta={}", s12 - s8);
    }

    #[test]
    fn activations_q7_8_above_40db() {
        // unit-variance activations at Q7.8: plenty of SQNR — the paper's
        // "no noticeable loss" regime
        let xs = gaussian(1.0, 20_000, 2);
        assert!(sqnr_db(&xs, 8) > 40.0);
        assert_eq!(saturation_rate(&xs, 8), 0.0);
    }

    #[test]
    fn weights_need_finer_grid() {
        // fused weights ~N(0, 0.05): Q7.8 leaves ~33 dB (marginal),
        // Q3.12 recovers > 40 dB — why WEIGHT_FRAC = 12
        let xs = gaussian(0.05, 20_000, 3);
        assert!(sqnr_db(&xs, 8) < 36.0);
        assert!(sqnr_db(&xs, 12) > 40.0);
        assert!(sqnr_db(&xs, 12) > sqnr_db(&xs, 8) + 15.0);
    }

    #[test]
    fn calibration_balances_range_and_resolution() {
        let wide = gaussian(20.0, 20_000, 4); // needs range → low frac
        let narrow = gaussian(0.02, 20_000, 5); // needs resolution → high frac
        let (fw, _) = calibrate_frac(&wide, 1e-3);
        let (fn_, _) = calibrate_frac(&narrow, 1e-3);
        assert!(fw < fn_, "wide={fw} narrow={fn_}");
        assert!(fn_ >= 12);
    }

    #[test]
    fn saturation_rate_detects_clipping() {
        let xs = vec![1000.0f32; 100];
        assert_eq!(saturation_rate(&xs, 8), 1.0);
        assert!(saturation_rate(&xs, 4) < 1.0); // Q11.4 range ±2048
    }
}
