//! Quantised fused-weight loading for the simulator's functional datapath.
//!
//! `python/compile/fusion.write_weights` emits `weights_micro.bin` (int16
//! little-endian, C-order) plus a JSON manifest of tensor names, shapes and
//! byte offsets. Names follow the flattened pytree convention, e.g.
//! `stages.0.blocks.1.attn.wqkv`. Values are Q3.12 weights / Q7.8 biases
//! (see the manifest's `weight_frac` / `data_frac`).

use std::collections::HashMap;
use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug)]
struct ManifestTensor {
    name: String,
    shape: Vec<usize>,
    offset: usize,
    len: usize,
}

#[derive(Debug)]
struct Manifest {
    tensors: Vec<ManifestTensor>,
    weight_frac: u32,
    data_frac: u32,
}

impl Manifest {
    fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let need = |v: Option<usize>, what: &str| {
            v.with_context(|| format!("manifest missing {what}"))
        };
        let mut tensors = Vec::new();
        for t in j
            .get("tensors")
            .and_then(Json::as_arr)
            .context("manifest missing tensors[]")?
        {
            tensors.push(ManifestTensor {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .context("tensor missing name")?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .context("tensor missing shape")?
                    .iter()
                    .map(|d| d.as_usize().context("bad dim"))
                    .collect::<Result<_>>()?,
                offset: need(t.get("offset").and_then(Json::as_usize), "offset")?,
                len: need(t.get("len").and_then(Json::as_usize), "len")?,
            });
        }
        Ok(Manifest {
            tensors,
            weight_frac: need(j.get("weight_frac").and_then(Json::as_usize), "weight_frac")?
                as u32,
            data_frac: need(j.get("data_frac").and_then(Json::as_usize), "data_frac")? as u32,
        })
    }
}

/// A named int tensor (row-major).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl Tensor {
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// All tensors of a quantised model, keyed by flattened pytree path.
#[derive(Debug)]
pub struct WeightStore {
    pub tensors: HashMap<String, Tensor>,
    pub weight_frac: u32,
    pub data_frac: u32,
}

impl WeightStore {
    /// Load `weights_*.bin` + its manifest.
    pub fn load(bin_path: &Path, manifest_path: &Path) -> Result<Self> {
        let manifest = Manifest::parse(
            &fs::read_to_string(manifest_path)
                .with_context(|| format!("reading {}", manifest_path.display()))?,
        )?;
        let blob = fs::read(bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        let mut tensors = HashMap::new();
        for t in manifest.tensors {
            let end = t.offset + t.len * 2;
            if end > blob.len() {
                bail!("tensor {} overruns blob ({} > {})", t.name, end, blob.len());
            }
            if t.shape.iter().product::<usize>() != t.len {
                bail!("tensor {} shape/len mismatch", t.name);
            }
            let data: Vec<i32> = blob[t.offset..end]
                .chunks_exact(2)
                .map(|c| i16::from_le_bytes([c[0], c[1]]) as i32)
                .collect();
            tensors.insert(
                t.name,
                Tensor {
                    shape: t.shape,
                    data,
                },
            );
        }
        Ok(WeightStore {
            tensors,
            weight_frac: manifest.weight_frac,
            data_frac: manifest.data_frac,
        })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor {name}"))
    }

    /// Convenience: 2-D weight matrix (rows = in, cols = out).
    pub fn matrix(&self, name: &str) -> Result<&Tensor> {
        let t = self.get(name)?;
        if t.shape.len() != 2 {
            bail!("{name} is not 2-D: {:?}", t.shape);
        }
        Ok(t)
    }

    /// Convenience: 1-D bias vector.
    pub fn vector(&self, name: &str) -> Result<&Tensor> {
        let t = self.get(name)?;
        if t.shape.len() != 1 {
            bail!("{name} is not 1-D: {:?}", t.shape);
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fixture(dir: &Path) -> (std::path::PathBuf, std::path::PathBuf) {
        let bin = dir.join("w.bin");
        let man = dir.join("w.json");
        let vals: [i16; 6] = [1, -2, 300, -400, 5, 32767];
        let mut f = fs::File::create(&bin).unwrap();
        for v in vals {
            f.write_all(&v.to_le_bytes()).unwrap();
        }
        fs::write(
            &man,
            r#"{"tensors":[
                {"name":"a.w","shape":[2,2],"offset":0,"len":4},
                {"name":"a.b","shape":[2],"offset":8,"len":2}],
               "weight_frac":12,"data_frac":8}"#,
        )
        .unwrap();
        (bin, man)
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("swin_fpga_wtest");
        fs::create_dir_all(&dir).unwrap();
        let (bin, man) = fixture(&dir);
        let ws = WeightStore::load(&bin, &man).unwrap();
        assert_eq!(ws.weight_frac, 12);
        let w = ws.matrix("a.w").unwrap();
        assert_eq!(w.data, vec![1, -2, 300, -400]);
        let b = ws.vector("a.b").unwrap();
        assert_eq!(b.data, vec![5, 32767]);
        assert!(ws.get("nope").is_err());
        assert!(ws.vector("a.w").is_err());
        assert!(ws.matrix("a.b").is_err());
    }

    #[test]
    fn detects_overrun() {
        let dir = std::env::temp_dir().join("swin_fpga_wtest2");
        fs::create_dir_all(&dir).unwrap();
        let (bin, man) = fixture(&dir);
        fs::write(
            &man,
            r#"{"tensors":[{"name":"x","shape":[100],"offset":0,"len":100}],
               "weight_frac":12,"data_frac":8}"#,
        )
        .unwrap();
        assert!(WeightStore::load(&bin, &man).is_err());
    }
}
