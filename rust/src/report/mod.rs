//! Report generation: every table and figure of the paper's evaluation,
//! rendered as ASCII tables with paper-reference values alongside our
//! measured/simulated ones (the paper-vs-measured contract of
//! EXPERIMENTS.md).

use crate::accel::power::{accelerator_power_w, energy_efficiency, Activity};
use crate::accel::resources::{
    accelerator_resources, gcu_resources, mmu_resources, scu_resources, XCZU19EG,
};
use crate::accel::sim::{SimResult, Simulator};
use crate::accel::AccelConfig;
use crate::baseline::{cpu, gpu};
use crate::model::config::{SwinVariant, BASE, SMALL, TINY};
use crate::model::flops;
use crate::model::graph::WorkloadGraph;

/// Minimal fixed-width table printer.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        writeln!(f, "== {} ==", self.title)?;
        let line = |f: &mut std::fmt::Formatter<'_>, cells: &[String]| {
            for (c, w) in cells.iter().zip(&widths) {
                write!(f, "| {c:<w$} ").ok();
            }
            writeln!(f, "|")
        };
        line(f, &self.header)?;
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        writeln!(f, "{}", "-".repeat(total))?;
        for row in &self.rows {
            line(f, row)?;
        }
        Ok(())
    }
}

pub fn paper_variants() -> [&'static SwinVariant; 3] {
    [&TINY, &SMALL, &BASE]
}

fn sim_of(v: &'static SwinVariant) -> SimResult {
    Simulator::new(v, AccelConfig::paper()).simulate_inference()
}

/// Paper Table V reference rows: (variant, fps, gops, power).
pub const PAPER_TABLE5: [(&str, f64, f64, f64); 3] = [
    ("swin-t", 48.1, 431.2, 10.69),
    ("swin-s", 25.0, 436.4, 10.69),
    ("swin-b", 13.1, 403.5, 11.11),
];

pub fn paper_fps(name: &str) -> f64 {
    PAPER_TABLE5
        .iter()
        .find(|(n, ..)| *n == name)
        .map(|(_, f, ..)| *f)
        .unwrap_or(f64::NAN)
}

/// Table III: submodule resource utilisation.
pub fn table3_submodules() -> String {
    let cfg = AccelConfig::paper();
    let mut t = Table::new(
        "Table III — submodule resources (ours vs paper)",
        &["Submodule", "DSP", "DSP(paper)", "LUT", "LUT(paper)", "FF", "FF(paper)", "BRAM", "BRAM(paper)"],
    );
    let rows = [
        ("MMU", mmu_resources(&cfg), (1568u32, 198_960u32, 14_115u32, 14u32)),
        ("SCU", scu_resources(&cfg), (49, 41_184, 18_708, 4)),
        ("GCU", gcu_resources(&cfg), (98, 53_482, 5_745, 4)),
    ];
    for (name, r, (pd, pl, pf, pb)) in rows {
        t.row(&[
            name.to_string(),
            r.dsp.to_string(),
            pd.to_string(),
            r.lut.to_string(),
            pl.to_string(),
            r.ff.to_string(),
            pf.to_string(),
            r.bram.to_string(),
            pb.to_string(),
        ]);
    }
    t.to_string()
}

/// Table IV: whole-accelerator resources per variant.
pub fn table4_accelerators() -> String {
    let cfg = AccelConfig::paper();
    let mut t = Table::new(
        "Table IV — accelerator resources (ours; paper: T/S 1727 DSP 434k LUT 271k FF 244 BRAM, B 1733/451k/378k/338)",
        &["Model", "DSP", "DSP%", "LUT", "LUT%", "FF", "FF%", "BRAM", "BRAM%"],
    );
    for v in paper_variants() {
        let r = accelerator_resources(v, &cfg);
        let (du, lu, fu, bu) = r.utilisation(&XCZU19EG);
        t.row(&[
            v.name.to_string(),
            r.dsp.to_string(),
            format!("{:.1}%", du * 100.0),
            r.lut.to_string(),
            format!("{:.1}%", lu * 100.0),
            r.ff.to_string(),
            format!("{:.1}%", fu * 100.0),
            r.bram.to_string(),
            format!("{:.1}%", bu * 100.0),
        ]);
    }
    t.to_string()
}

/// Table V: comparison with related accelerators.
pub fn table5_comparison() -> String {
    let cfg = AccelConfig::paper();
    let mut t = Table::new(
        "Table V — comparison with related Swin accelerators",
        &["Design", "Model", "Platform", "MHz", "Precision", "Power(W)", "FPS", "GOPS", "DSPs"],
    );
    // related work rows, as printed in the paper
    t.row(&["[10] ViA".into(), "Swin-T".into(), "Alveo U50".into(), "300".into(), "fp16".into(), "39".into(), "*".into(), "309.6".into(), "2420".into()]);
    t.row(&["[11] ViTA".into(), "Swin-T".into(), "XC7Z020".into(), "150".into(), "fix8".into(), "0.88".into(), "8.71".into(), "*".into(), "*".into()]);
    t.row(&["[12]".into(), "WinAttn".into(), "ZCU102".into(), "100".into(), "fix8".into(), "*".into(), "*".into(), "75.17".into(), "70".into()]);
    for (v, paper) in paper_variants().iter().zip(PAPER_TABLE5) {
        let r = sim_of(v);
        let p = accelerator_power_w(v, &cfg, &r, Activity::from_sim(&r));
        let res = accelerator_resources(v, &cfg);
        t.row(&[
            "Ours (sim)".into(),
            v.name.into(),
            "XCZU19EG".into(),
            "200".into(),
            "fix16".into(),
            format!("{p:.2}"),
            format!("{:.1}", r.fps()),
            format!("{:.1}", r.gops()),
            res.dsp.to_string(),
        ]);
        t.row(&[
            "Ours (paper)".into(),
            v.name.into(),
            "XCZU19EG".into(),
            "200".into(),
            "fix16".into(),
            format!("{:.2}", paper.3),
            format!("{:.1}", paper.1),
            format!("{:.1}", paper.2),
            if v.name == "swin-b" { "1733".into() } else { "1727".into() },
        ]);
    }
    t.to_string()
}

/// Fig. 11: relative speedup vs CPU and GPU.
pub fn fig11_speedup() -> String {
    let mut t = Table::new(
        "Fig. 11 — relative speedup (accelerator ÷ device)",
        &["Model", "FPGA FPS", "CPU FPS", "vs CPU", "paper", "GPU FPS", "vs GPU", "paper"],
    );
    let paper_cpu = [1.76, 1.66, 1.25];
    let paper_gpu = [0.20, 0.17, 0.12];
    for (i, v) in paper_variants().iter().enumerate() {
        let r = sim_of(v);
        let c = cpu::point(v);
        let g = gpu::point(v);
        t.row(&[
            v.name.to_string(),
            format!("{:.1}", r.fps()),
            format!("{:.1}", c.fps),
            format!("{:.2}x", r.fps() / c.fps),
            format!("{:.2}x", paper_cpu[i]),
            format!("{:.1}", g.fps),
            format!("{:.2}x", r.fps() / g.fps),
            format!("{:.2}x", paper_gpu[i]),
        ]);
    }
    t.to_string()
}

/// Fig. 12: energy efficiency (FPS/W) ratios.
pub fn fig12_energy() -> String {
    let cfg = AccelConfig::paper();
    let mut t = Table::new(
        "Fig. 12 — energy efficiency (FPS/W) and improvement ratios",
        &["Model", "FPGA FPS/W", "CPU FPS/W", "vs CPU", "paper", "GPU FPS/W", "vs GPU", "paper"],
    );
    let paper_cpu = [20.45, 18.60, 14.63];
    let paper_gpu = [5.05, 4.42, 3.00];
    for (i, v) in paper_variants().iter().enumerate() {
        let r = sim_of(v);
        let p = accelerator_power_w(v, &cfg, &r, Activity::from_sim(&r));
        let fe = energy_efficiency(r.fps(), p);
        let c = cpu::point(v);
        let g = gpu::point(v);
        t.row(&[
            v.name.to_string(),
            format!("{fe:.2}"),
            format!("{:.3}", c.efficiency()),
            format!("{:.1}x", fe / c.efficiency()),
            format!("{:.1}x", paper_cpu[i]),
            format!("{:.3}", g.efficiency()),
            format!("{:.2}x", fe / g.efficiency()),
            format!("{:.2}x", paper_gpu[i]),
        ]);
    }
    t.to_string()
}

/// §V.A: invalid computation analysis (Eq. 17 + exact graph count).
pub fn sec5a_invalid() -> String {
    let mut t = Table::new(
        "§V.A — invalid computation U (paper: 1.2%)",
        &["Model", "U (Eq.17 closed form)", "U (exact graph)", "padded GMACs", "logical GMACs"],
    );
    for v in paper_variants() {
        let g = WorkloadGraph::build(v);
        t.row(&[
            v.name.to_string(),
            format!("{:.2}%", flops::invalid_fraction_variant(v) * 100.0),
            format!("{:.2}%", g.invalid_fraction() * 100.0),
            format!("{:.2}", g.total_padded_macs() as f64 / 1e9),
            format!("{:.2}", g.total_macs() as f64 / 1e9),
        ]);
    }
    t.to_string()
}

/// Per-run simulator summary (CLI `simulate`).
pub fn render_sim_result(v: &SwinVariant, r: &SimResult) -> String {
    let cfg = r.cfg.clone();
    let power = accelerator_power_w(v, &cfg, r, Activity::from_sim(r));
    let mut s = format!(
        "{}: {:.2} ms/frame  {:.1} FPS  {:.1} GOPS  {:.2} W  (paper: {:.1} FPS)\n",
        v.name,
        r.latency_ms(),
        r.fps(),
        r.gops(),
        power,
        paper_fps(v.name),
    );
    s.push_str(&format!(
        "  cycles: total {}  mmu {}  mem {}  nonlinear {} (exposed {})\n",
        r.total_cycles, r.mmu_cycles, r.mem_cycles, r.nonlinear_cycles, r.nonlinear_exposed
    ));
    s.push_str(&format!(
        "  MMU utilisation {:.1}%  memory-bound: {}\n",
        r.mmu_utilization() * 100.0,
        r.memory_bound()
    ));
    for (i, c) in r.per_stage_cycles.iter().enumerate() {
        s.push_str(&format!("  stage {i}: {c} cycles ({:.2} ms)\n", cfg.cycles_to_ms(*c)));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_nonempty() {
        for s in [
            table3_submodules(),
            table4_accelerators(),
            table5_comparison(),
            fig11_speedup(),
            fig12_energy(),
            sec5a_invalid(),
        ] {
            assert!(s.lines().count() > 3, "{s}");
        }
    }

    #[test]
    fn table_formatting_alignment() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(&["xxx".into(), "y".into()]);
        let s = t.to_string();
        assert!(s.contains("| xxx | y  |"));
    }

    #[test]
    fn fig11_contains_paper_anchor() {
        let s = fig11_speedup();
        assert!(s.contains("1.76x"));
        assert!(s.contains("0.20x"));
    }
}
