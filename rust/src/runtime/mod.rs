//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs here — the artifacts are self-contained HLO.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialises HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Tensor spec from the artifact manifest.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            shape: j
                .get("shape")
                .and_then(Json::as_arr)
                .context("missing shape")?
                .iter()
                .map(|v| v.as_usize().context("bad dim"))
                .collect::<Result<_>>()?,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .to_string(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: String,
    pub batch: Option<usize>,
    /// Swin variant this artifact was compiled from (when the manifest
    /// records it) — lets the serving layer warm its service estimates
    /// from the cycle model before the first launch is measured.
    pub variant: Option<String>,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// The artifact manifest (artifacts/manifest.json).
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut artifacts = HashMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing artifacts{}")?
        {
            let inputs = if let Some(arr) = a.get("inputs").and_then(Json::as_arr) {
                arr.iter().map(TensorSpec::from_json).collect::<Result<_>>()?
            } else if let Some(inp) = a.get("input") {
                vec![TensorSpec::from_json(inp)?]
            } else {
                bail!("artifact {name} has no inputs");
            };
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    kind: a
                        .get("kind")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string(),
                    batch: a.get("batch").and_then(Json::as_usize),
                    variant: a
                        .get("variant")
                        .and_then(Json::as_str)
                        .map(str::to_string),
                    inputs,
                    output: TensorSpec::from_json(
                        a.get("output").context("artifact missing output")?,
                    )?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }
}

/// Typed input/output buffers.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => bail!("expected i32 tensor"),
        }
    }
}

/// A compiled executable for one artifact.
pub struct Engine {
    pub info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

impl Engine {
    /// Execute with positional inputs; returns the single tuple output.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Tensor> {
        anyhow::ensure!(
            inputs.len() == self.info.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.info.name,
            self.info.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.info.inputs) {
            anyhow::ensure!(
                t.len() == spec.numel(),
                "{}: input size {} != spec {:?}",
                self.info.name,
                t.len(),
                spec.shape
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = match t {
                Tensor::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
                Tensor::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(match self.info.output.dtype.as_str() {
            "i32" => Tensor::I32(out.to_vec::<i32>()?),
            _ => Tensor::F32(out.to_vec::<f32>()?),
        })
    }
}

/// The runtime: one PJRT CPU client + lazily compiled engines.
///
/// PJRT execution is not assumed thread-safe; the serving layer funnels
/// calls through a single executor thread (see `server`).
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    engines: Mutex<HashMap<String, &'static Engine>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            engines: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the engine for an artifact file name.
    /// Engines are leaked to 'static: they live for the process and this
    /// keeps the hot path free of locks around execution.
    pub fn engine(&self, name: &str) -> Result<&'static Engine> {
        if let Some(e) = self.engines.lock().unwrap().get(name) {
            return Ok(e);
        }
        let info = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("unknown artifact {name}"))?
            .clone();
        let path = self.manifest.dir.join(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let eng: &'static Engine = Box::leak(Box::new(Engine { info, exe }));
        self.engines.lock().unwrap().insert(name.to_string(), eng);
        Ok(eng)
    }

    /// Names of float serving artifacts, sorted ascending by batch size.
    pub fn serving_artifacts(&self) -> Vec<(usize, String)> {
        let mut v: Vec<(usize, String)> = self
            .manifest
            .artifacts
            .values()
            .filter(|a| a.kind == "swin_float")
            .map(|a| (a.batch.unwrap_or(1), a.name.clone()))
            .collect();
        v.sort();
        v
    }
}

/// Self-test: run the MMU kernel artifact against the functional Rust MMU
/// — outputs must be **bit-identical** — and smoke-run a serving model.
pub fn selftest(artifacts_dir: &Path) -> Result<()> {
    use crate::accel::mmu::Mmu;
    use crate::accel::tiling::IntMat;
    use crate::accel::AccelConfig;
    use crate::util::prng::Rng;

    let rt = Runtime::new(artifacts_dir)?;
    println!("PJRT platform: {}", rt.platform());

    let eng = rt.engine("kernel_mmu.hlo.txt")?;
    let a_spec = &eng.info.inputs[0];
    let b_spec = &eng.info.inputs[1];
    let mut rng = Rng::new(0xC0FFEE);
    let a: Vec<i32> = (0..a_spec.numel()).map(|_| rng.range_i32(-2000, 2000)).collect();
    let b: Vec<i32> = (0..b_spec.numel()).map(|_| rng.range_i32(-2000, 2000)).collect();
    let out = eng.run(&[Tensor::I32(a.clone()), Tensor::I32(b.clone())])?;
    let am = IntMat::from_vec(a_spec.shape[0], a_spec.shape[1], a);
    let bm = IntMat::from_vec(b_spec.shape[0], b_spec.shape[1], b);
    let want = Mmu::new(AccelConfig::paper()).gemm(&am, &bm, crate::fixed::WEIGHT_FRAC);
    anyhow::ensure!(
        out.as_i32()? == want.data.as_slice(),
        "MMU kernel-vs-functional mismatch"
    );
    println!("MMU kernel ↔ functional model: bit-exact ✓");

    if let Some((batch, name)) = rt.serving_artifacts().first() {
        let eng = rt.engine(name)?;
        let n = eng.info.inputs[0].numel();
        let img: Vec<f32> = (0..n).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let out = eng.run(&[Tensor::F32(img)])?;
        anyhow::ensure!(out.len() == eng.info.output.numel());
        println!("serving artifact {name} (batch {batch}) runs ✓");
    }
    Ok(())
}
