//! Per-card continuous-batch formation: the queue + launch-decision core
//! shared by the wall-clock executor loop ([`super::Server`]) and the
//! virtual-time fleet router ([`super::router::Router`]).
//!
//! [`CardBatcher`] is deliberately time-unit agnostic: timestamps are
//! opaque `u64` *ticks* (nanoseconds since the executor started on the
//! wall-clock side, accelerator cycles on the router side), so the exact
//! same deadline arithmetic and seat-selection policy is exercised — and
//! tested — in both worlds.
//!
//! ## SLO classes
//!
//! Every request carries an [`Slo`] class with a per-class flush deadline
//! ([`SloPolicy`]): `Interactive` requests tolerate only a short batching
//! wait, `Batch` requests trade wait for occupancy. Batch formation is
//! deadline-aware on both ends:
//!
//! * **flush timing** — the flush fires at the *earliest* queued
//!   deadline (`enqueued + max_wait(class)`), so one overdue interactive
//!   request flushes a bucket early even while batch traffic would
//!   happily keep waiting;
//! * **seat selection** ([`CardBatcher::take_launch`]) — overdue
//!   interactive requests board first (the class-SLO guarantee: past its
//!   deadline an interactive request waits at most one more launch),
//!   then overdue batch requests by deadline (the aging path that keeps
//!   batch traffic from starving), then remaining seats prefer the
//!   most-urgent class so launches stay class-homogeneous, then FIFO.
//!
//! For single-class traffic (every request the same [`Slo`], as when no
//! [`SloPolicy`] is configured) this degenerates exactly to the PR-1
//! single-deadline FIFO batcher: one deadline ladder, arrival-order
//! seats.
//!
//! Energy-aware routing and idle-card power gating (PR 9) live entirely
//! above this layer: the router prices a gated card's wake-up fill into
//! the *service* span it books after [`CardBatcher::take_launch`], so
//! batch formation — deadlines, seat order, `fire_at` — is identical
//! whether or not the fleet gates idle cards.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

/// Request service class (per-request SLO).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Slo {
    /// Latency-sensitive: short batching wait (UI, autonomous-driving
    /// frames — the paper's edge scenarios).
    Interactive,
    /// Throughput traffic: long batching wait, high occupancy.
    Batch,
}

impl Slo {
    pub const ALL: [Slo; 2] = [Slo::Interactive, Slo::Batch];

    /// Dense index (metrics arrays).
    pub fn idx(self) -> usize {
        match self {
            Slo::Interactive => 0,
            Slo::Batch => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Slo::Interactive => "interactive",
            Slo::Batch => "batch",
        }
    }
}

/// Per-class flush deadlines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SloPolicy {
    pub interactive_max_wait: Duration,
    pub batch_max_wait: Duration,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            interactive_max_wait: Duration::from_millis(2),
            batch_max_wait: Duration::from_millis(20),
        }
    }
}

impl SloPolicy {
    /// Both classes share one deadline (the pre-SLO behaviour).
    pub fn uniform(max_wait: Duration) -> Self {
        SloPolicy {
            interactive_max_wait: max_wait,
            batch_max_wait: max_wait,
        }
    }
}

/// Greedy largest-fit decomposition of `n` pending requests into the
/// available engine batch sizes (descending). Returns the batch sizes to
/// launch, covering all `n`.
pub fn decompose(n: usize, sizes_desc: &[usize]) -> Vec<usize> {
    let mut rem = n;
    let mut plan = Vec::new();
    for &s in sizes_desc {
        while rem >= s {
            plan.push(s);
            rem -= s;
        }
    }
    if rem > 0 {
        // smaller than the smallest engine: pad up to it
        plan.push(*sizes_desc.last().expect("no engine sizes"));
    }
    plan
}

/// The single next launch for a queue of `n` requests: the largest bucket
/// the queue fills, or the smallest bucket (padded) when it fills none.
pub fn pick_launch(n: usize, sizes_desc: &[usize]) -> usize {
    sizes_desc
        .iter()
        .copied()
        .find(|&s| s <= n)
        .unwrap_or_else(|| *sizes_desc.last().expect("no engine sizes"))
}

/// One queued request: an opaque payload plus the state batch formation
/// needs (class and enqueue tick).
#[derive(Debug)]
pub struct BatchItem<T> {
    pub payload: T,
    pub class: Slo,
    /// Tick the request entered the system (deadline anchor).
    pub enqueued: u64,
}

impl<T> BatchItem<T> {
    fn deadline(&self, wait: &[u64; 2]) -> u64 {
        self.enqueued.saturating_add(wait[self.class.idx()])
    }
}

/// What the batcher wants to do next (evaluated at a given tick).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// Launch a bucket of this size now.
    Launch(usize),
    /// Wait for arrivals, but no later than this tick (earliest queued
    /// deadline).
    Wait(u64),
    /// Queue empty.
    Idle,
}

/// Per-card queue plus batch-formation state — the `continuous_loop`
/// decision logic from `server::mod`, factored out so the fleet router
/// can run one per card in virtual time.
#[derive(Debug)]
pub struct CardBatcher<T> {
    /// Supported launch sizes, descending (the artifact buckets).
    /// Shared (`Arc`): a fleet of cards with the same ladder holds one
    /// allocation, and [`Self::reset`] never re-clones it.
    sizes: Arc<[usize]>,
    max_batch: usize,
    /// Queue bound: at or past it the batcher launches immediately
    /// rather than waiting out a deadline.
    cap: usize,
    /// Per-class max wait in ticks, indexed by [`Slo::idx`].
    wait: [u64; 2],
    queue: VecDeque<BatchItem<T>>,
    /// Tick of the latest enqueue (when the queue state last grew).
    changed_at: u64,
    /// Per-class earliest queued *deadline* (`u64::MAX` when the class
    /// has nothing queued), indexed by [`Slo::idx`]. Makes
    /// [`Self::flush_due`] — and hence [`Self::fire_at`] — O(1) instead
    /// of a full-queue scan. Maintained as an exact min, not a
    /// last-pushed value: enqueue ticks are NOT monotone (the wall-clock
    /// executor stamps submission time, which can arrive out of order),
    /// so `push` takes `min` and removal recomputes from what remains.
    due_head: [u64; 2],
    /// Per-class queued count, indexed by [`Slo::idx`].
    class_n: [usize; 2],
}

impl<T> CardBatcher<T> {
    pub fn new(
        sizes_desc: impl Into<Arc<[usize]>>,
        max_batch: usize,
        cap: usize,
        wait: [u64; 2],
    ) -> Self {
        let sizes = sizes_desc.into();
        assert!(!sizes.is_empty(), "batcher needs at least one bucket");
        CardBatcher {
            sizes,
            max_batch: max_batch.max(1),
            cap: cap.max(1),
            wait,
            queue: VecDeque::new(),
            changed_at: 0,
            due_head: [u64::MAX; 2],
            class_n: [0; 2],
        }
    }

    /// Drop all queued requests and rewind the enqueue clock — a new
    /// experiment on the same card, with the bucket ladder (and its
    /// single shared allocation) kept.
    pub fn reset(&mut self) {
        self.queue.clear();
        self.changed_at = 0;
        self.due_head = [u64::MAX; 2];
        self.class_n = [0; 2];
    }

    /// Recompute the per-class deadline heads from the queue — O(n),
    /// called only when requests *leave* (a min can rise on removal but
    /// only fall on insert).
    fn rescan_heads(&mut self) {
        self.due_head = [u64::MAX; 2];
        self.class_n = [0; 2];
        for it in &self.queue {
            let c = it.class.idx();
            self.class_n[c] += 1;
            self.due_head[c] = self.due_head[c].min(it.deadline(&self.wait));
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Enqueue one request. `enqueued` is the tick its deadline is
    /// anchored to (its submission tick, which may predate the call).
    pub fn push(&mut self, payload: T, class: Slo, enqueued: u64) {
        self.changed_at = self.changed_at.max(enqueued);
        let it = BatchItem {
            payload,
            class,
            enqueued,
        };
        let c = class.idx();
        self.class_n[c] += 1;
        self.due_head[c] = self.due_head[c].min(it.deadline(&self.wait));
        self.queue.push_back(it);
    }

    /// Earliest queued deadline, if any — O(1) over the per-class heads.
    pub fn flush_due(&self) -> Option<u64> {
        let due = Slo::ALL
            .iter()
            .filter(|c| self.class_n[c.idx()] > 0)
            .map(|c| self.due_head[c.idx()])
            .min();
        debug_assert_eq!(
            due,
            self.flush_due_scan(),
            "per-class deadline heads diverged from the queue scan"
        );
        due
    }

    /// The pre-head full-queue scan, retained as the differential oracle
    /// for the O(1) heads (checked on every [`Self::flush_due`] in debug
    /// builds).
    #[doc(hidden)]
    pub fn flush_due_scan(&self) -> Option<u64> {
        self.queue.iter().map(|it| it.deadline(&self.wait)).min()
    }

    /// The bucket a deadline/cap flush would launch right now (the
    /// single source of launch sizing — the executor loop reuses it for
    /// its shutdown-drain and deadline-timeout flushes).
    pub fn flush_launch(&self) -> usize {
        pick_launch(self.queue.len().min(self.max_batch), &self.sizes)
    }

    /// Whether the queue can launch without waiting (full bucket formed,
    /// or the queue bound reached).
    fn launch_ready(&self) -> bool {
        let full = pick_launch(self.max_batch, &self.sizes);
        self.queue.len() >= full || self.queue.len() >= self.cap
    }

    /// Decide at tick `now`: launch, wait until a deadline, or idle.
    pub fn step(&self, now: u64) -> Step {
        if self.queue.is_empty() {
            return Step::Idle;
        }
        if self.launch_ready() {
            return Step::Launch(self.flush_launch());
        }
        let due = self.flush_due().expect("non-empty queue has a deadline");
        if now >= due {
            Step::Launch(self.flush_launch())
        } else {
            Step::Wait(due)
        }
    }

    /// Earliest tick at or after `busy_free` at which this queue will
    /// launch *absent new arrivals*: immediately once the card frees when
    /// a full bucket is ready, else at the earliest queued deadline.
    /// Drives the router's event-driven virtual-time advance.
    pub fn fire_at(&self, busy_free: u64) -> Option<u64> {
        if self.queue.is_empty() {
            return None;
        }
        if self.launch_ready() {
            Some(busy_free.max(self.changed_at))
        } else {
            Some(busy_free.max(self.flush_due().expect("non-empty")))
        }
    }

    /// Take the requests for a launch of `launch` seats, evaluated at
    /// tick `now`. Seat order:
    ///
    /// 1. overdue **interactive** requests, earliest deadline first —
    ///    the class-SLO guarantee: once its deadline passes, an
    ///    interactive request boards the very next launch, even over an
    ///    arbitrarily deep overdue batch backlog;
    /// 2. overdue **batch** requests, earliest deadline first — the
    ///    aging path that keeps batch traffic moving;
    /// 3. non-overdue requests of the most-urgent class (bucket
    ///    homogeneity), FIFO;
    /// 4. the rest, FIFO.
    pub fn take_launch(&mut self, launch: usize, now: u64) -> Vec<BatchItem<T>> {
        let take = launch.min(self.queue.len());
        if take == 0 {
            return Vec::new();
        }
        // most-urgent class among requests whose deadline has NOT passed
        // (overdue requests board unconditionally via the first bands)
        let pref = self
            .queue
            .iter()
            .filter(|it| it.deadline(&self.wait) > now)
            .min_by_key(|it| (it.deadline(&self.wait), it.enqueued))
            .map_or(Slo::Interactive, |it| it.class);
        let mut order: Vec<usize> = (0..self.queue.len()).collect();
        order.sort_by_key(|&i| {
            let it = &self.queue[i];
            let deadline = it.deadline(&self.wait);
            if deadline <= now {
                (it.class.idx() as u8, deadline, it.enqueued)
            } else {
                (2u8, u64::from(it.class != pref), it.enqueued)
            }
        });
        // emit seats in band order (leftovers keep FIFO queue order)
        let mut slots: Vec<Option<BatchItem<T>>> =
            std::mem::take(&mut self.queue).into_iter().map(Some).collect();
        let mut out = Vec::with_capacity(take);
        for &i in order.iter().take(take) {
            out.push(slots[i].take().expect("each index selected once"));
        }
        self.queue = slots.into_iter().flatten().collect();
        self.rescan_heads();
        out
    }

    /// Remove **every** queued request, in FIFO queue order, leaving the
    /// batcher empty but otherwise reusable. This is the draining path
    /// for a card leaving the fleet (or crashing): the router feeds each
    /// returned item — original class and enqueue tick intact — back
    /// through its normal assignment path, so each request is
    /// redistributed exactly once and its deadline anchor survives the
    /// move.
    pub fn drain_all(&mut self) -> Vec<BatchItem<T>> {
        let out: Vec<BatchItem<T>> = std::mem::take(&mut self.queue).into();
        self.due_head = [u64::MAX; 2];
        self.class_n = [0; 2];
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIZES: [usize; 4] = [8, 4, 2, 1];

    fn batcher(max_batch: usize, cap: usize, wait: [u64; 2]) -> CardBatcher<u64> {
        CardBatcher::new(SIZES.to_vec(), max_batch, cap, wait)
    }

    #[test]
    fn decompose_greedy_largest_fit() {
        let sizes = [8usize, 4, 2, 1];
        assert_eq!(decompose(8, &sizes), vec![8]);
        assert_eq!(decompose(7, &sizes), vec![4, 2, 1]);
        assert_eq!(decompose(13, &sizes), vec![8, 4, 1]);
        assert_eq!(decompose(1, &sizes), vec![1]);
        assert!(decompose(0, &sizes).is_empty());
    }

    #[test]
    fn decompose_pads_below_minimum() {
        let sizes = [8usize, 4];
        // 3 requests with a min engine of 4: run one padded batch of 4
        assert_eq!(decompose(3, &sizes), vec![4]);
    }

    #[test]
    fn pick_launch_largest_fit_or_pad() {
        let sizes = [8usize, 4, 2, 1];
        assert_eq!(pick_launch(13, &sizes), 8);
        assert_eq!(pick_launch(8, &sizes), 8);
        assert_eq!(pick_launch(5, &sizes), 4);
        assert_eq!(pick_launch(1, &sizes), 1);
        // below the smallest bucket: pad up to it
        assert_eq!(pick_launch(3, &[8, 4]), 4);
    }

    #[test]
    fn empty_queue_is_idle() {
        let b = batcher(8, 256, [100, 100]);
        assert_eq!(b.step(0), Step::Idle);
        assert_eq!(b.fire_at(0), None);
        assert!(b.flush_due().is_none());
    }

    #[test]
    fn full_bucket_launches_immediately() {
        let mut b = batcher(8, 256, [1_000, 1_000]);
        for i in 0..8 {
            b.push(i, Slo::Batch, 10 + i);
        }
        assert_eq!(b.step(17), Step::Launch(8));
        // card busy until 40: fires the moment it frees
        assert_eq!(b.fire_at(40), Some(40));
        // card already idle: fires when the bucket filled
        assert_eq!(b.fire_at(0), Some(17));
    }

    #[test]
    fn partial_queue_waits_until_earliest_deadline() {
        let mut b = batcher(8, 256, [50, 500]);
        b.push(0, Slo::Batch, 100); // deadline 600
        b.push(1, Slo::Interactive, 140); // deadline 190 — the earliest
        assert_eq!(b.flush_due(), Some(190));
        assert_eq!(b.step(150), Step::Wait(190));
        // at the interactive deadline the queue flushes early (2 → bucket 2)
        assert_eq!(b.step(190), Step::Launch(2));
        assert_eq!(b.fire_at(0), Some(190));
        // busy card: flush as soon as it frees
        assert_eq!(b.fire_at(700), Some(700));
    }

    #[test]
    fn cap_forces_launch_without_waiting() {
        let mut b = batcher(8, 3, [1_000_000, 1_000_000]);
        b.push(0, Slo::Batch, 0);
        b.push(1, Slo::Batch, 0);
        assert!(matches!(b.step(1), Step::Wait(_)));
        b.push(2, Slo::Batch, 1);
        // at cap: launch the largest bucket 3 requests fill (= 2)
        assert_eq!(b.step(1), Step::Launch(2));
    }

    #[test]
    fn take_launch_overdue_first_then_class_then_fifo() {
        let mut b = batcher(8, 256, [50, 500]);
        b.push(0, Slo::Batch, 0); // deadline 500
        b.push(1, Slo::Interactive, 460); // deadline 510
        b.push(2, Slo::Batch, 480); // deadline 980
        b.push(3, Slo::Batch, 490); // deadline 990
        // now = 505: batch#0 (500) is overdue; most-urgent non-overdue
        // class is Interactive (510 < 980)
        let got: Vec<u64> = b
            .take_launch(2, 505)
            .into_iter()
            .map(|it| it.payload)
            .collect();
        assert_eq!(got, vec![0, 1], "overdue batch boards, then interactive");
        // leftovers keep FIFO order
        let rest: Vec<u64> = b.take_launch(8, 505).into_iter().map(|it| it.payload).collect();
        assert_eq!(rest, vec![2, 3]);
    }

    #[test]
    fn overdue_interactive_preempts_older_overdue_batch() {
        let mut b = batcher(8, 256, [50, 500]);
        // a deep, long-overdue batch backlog…
        for i in 0..6 {
            b.push(i, Slo::Batch, i); // deadlines ~500, long passed
        }
        b.push(9, Slo::Interactive, 2_000); // deadline 2050
        // …must not starve an overdue interactive request: it boards the
        // very next launch even though every batch deadline is earlier
        let got: Vec<u64> = b
            .take_launch(2, 3_000)
            .into_iter()
            .map(|it| it.payload)
            .collect();
        assert_eq!(got, vec![9, 0], "interactive first, then oldest batch");
    }

    #[test]
    fn take_launch_prefers_urgent_class_seats() {
        let mut b = batcher(8, 256, [50, 5_000]);
        b.push(0, Slo::Batch, 0); // deadline 5000
        b.push(1, Slo::Batch, 1); // deadline 5001
        b.push(2, Slo::Interactive, 10); // deadline 60 — most urgent
        b.push(3, Slo::Interactive, 20); // deadline 70
        // nothing overdue at 30: interactive seats first, FIFO inside
        let got: Vec<u64> = b
            .take_launch(3, 30)
            .into_iter()
            .map(|it| it.payload)
            .collect();
        assert_eq!(got, vec![2, 3, 0]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn single_class_traffic_degenerates_to_fifo() {
        // the PR-1 batcher semantics: one class, one deadline ladder —
        // seats go in strict arrival order whether or not overdue
        for now in [50u64, 500] {
            let mut b = batcher(8, 256, [100, 100]);
            for i in 0..6u64 {
                b.push(i, Slo::Interactive, i);
            }
            let got: Vec<u64> = b
                .take_launch(4, now)
                .into_iter()
                .map(|it| it.payload)
                .collect();
            assert_eq!(got, vec![0, 1, 2, 3], "now={now}");
        }
    }

    #[test]
    fn equal_waits_still_group_by_class() {
        // with per-class deadlines equal, urgency ties break toward
        // class-homogeneous buckets (batch items 0,2,4 share the
        // most-urgent class), never starving anyone (FIFO inside class)
        let mut b = batcher(8, 256, [100, 100]);
        for i in 0..6u64 {
            b.push(i, if i % 2 == 0 { Slo::Batch } else { Slo::Interactive }, i);
        }
        let got: Vec<u64> = b
            .take_launch(4, 50)
            .into_iter()
            .map(|it| it.payload)
            .collect();
        assert_eq!(got, vec![0, 2, 4, 1]);
    }

    #[test]
    fn reset_clears_queue_and_clock_but_keeps_the_ladder() {
        let mut b = batcher(8, 256, [100, 100]);
        for i in 0..5 {
            b.push(i, Slo::Batch, 40 + i);
        }
        assert_eq!(b.len(), 5);
        assert_eq!(b.fire_at(0), Some(140)); // deadline 40 + 100
        b.reset();
        assert!(b.is_empty());
        assert_eq!(b.step(0), Step::Idle);
        assert_eq!(b.fire_at(0), None);
        // the ladder still forms launches after a reset, from tick 0
        for i in 0..8 {
            b.push(i, Slo::Batch, i);
        }
        assert_eq!(b.step(7), Step::Launch(8));
        assert_eq!(b.fire_at(0), Some(7));
    }

    #[test]
    fn flush_due_survives_out_of_order_enqueue_ticks() {
        // Wall-clock executor ticks are submission stamps and are NOT
        // guaranteed monotone: a later push may carry an EARLIER tick.
        // The O(1) per-class heads must track the true minimum, not the
        // last-pushed deadline.
        let mut b = batcher(8, 256, [50, 500]);
        b.push(0, Slo::Batch, 400); // deadline 900
        b.push(1, Slo::Batch, 100); // deadline 600 — pushed later, due sooner
        assert_eq!(b.flush_due(), Some(600));
        assert_eq!(b.flush_due(), b.flush_due_scan());
        b.push(2, Slo::Interactive, 300); // deadline 350
        b.push(3, Slo::Interactive, 90); // deadline 140 — out of order again
        assert_eq!(b.flush_due(), Some(140));
        assert_eq!(b.fire_at(0), Some(400), "changed_at keeps the max tick");
        // removal can RAISE the min: take the two interactive requests
        // (both overdue at 1000) plus the due-600 batch one, and the head
        // must recompute to the remaining batch deadline
        let got: Vec<u64> =
            b.take_launch(3, 1_000).into_iter().map(|it| it.payload).collect();
        assert_eq!(got, vec![3, 2, 1], "overdue by class then deadline");
        assert_eq!(b.flush_due(), Some(900));
        assert_eq!(b.flush_due(), b.flush_due_scan());
        b.reset();
        assert_eq!(b.flush_due(), None);
    }

    #[test]
    fn flush_due_heads_match_scan_under_random_traffic() {
        use crate::util::prng::Rng;
        let mut rng = Rng::new(0xBA7C4);
        let mut b = batcher(8, 256, [30, 300]);
        for _ in 0..2_000 {
            // 2:1 push:drain mix with deliberately non-monotone ticks
            if rng.below(3) < 2 || b.is_empty() {
                let class =
                    if rng.below(2) == 0 { Slo::Interactive } else { Slo::Batch };
                b.push(rng.below(100), class, rng.below(10_000));
            } else {
                let n = 1 + rng.below(8) as usize;
                b.take_launch(n, rng.below(10_000));
            }
            assert_eq!(b.flush_due(), b.flush_due_scan());
        }
    }

    #[test]
    fn drain_all_returns_everything_exactly_once_in_fifo_order() {
        let mut b = batcher(8, 256, [50, 500]);
        for i in 0..5u64 {
            let class = if i % 2 == 0 { Slo::Batch } else { Slo::Interactive };
            b.push(i, class, 10 * i);
        }
        let drained = b.drain_all();
        let ids: Vec<u64> = drained.iter().map(|it| it.payload).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "FIFO order, nothing lost or duplicated");
        // class and enqueue tick survive the move (deadline anchors intact)
        assert_eq!(drained[1].class, Slo::Interactive);
        assert_eq!(drained[3].enqueued, 30);
        assert!(b.is_empty());
        assert_eq!(b.step(0), Step::Idle);
        assert_eq!(b.flush_due(), None);
        assert_eq!(b.flush_due(), b.flush_due_scan());
        // the batcher stays reusable after a drain
        b.push(9, Slo::Batch, 1_000);
        assert_eq!(b.flush_due(), Some(1_500));
    }

    #[test]
    fn drain_all_exact_under_out_of_order_enqueue_ticks() {
        // Enqueue ticks are NOT monotone (redispatched requests keep
        // their original, earlier ticks). Draining must still return the
        // exact multiset — including duplicate ticks — exactly once, and
        // leave the O(1) deadline heads consistent for reuse.
        let mut b = batcher(8, 256, [50, 500]);
        let ticks = [400u64, 100, 300, 100, 90, 250];
        for (i, &t) in ticks.iter().enumerate() {
            let class = if i % 3 == 0 { Slo::Interactive } else { Slo::Batch };
            b.push(i as u64, class, t);
        }
        let drained = b.drain_all();
        assert_eq!(drained.len(), ticks.len());
        let got: Vec<(u64, u64)> =
            drained.iter().map(|it| (it.payload, it.enqueued)).collect();
        let want: Vec<(u64, u64)> =
            ticks.iter().enumerate().map(|(i, &t)| (i as u64, t)).collect();
        assert_eq!(got, want, "every (id, tick) pair exactly once, in order");
        assert!(b.is_empty());
        // re-pushing the drained items elsewhere reproduces exact heads
        let mut other = batcher(8, 256, [50, 500]);
        for it in drained {
            other.push(it.payload, it.class, it.enqueued);
        }
        assert_eq!(other.len(), ticks.len());
        assert_eq!(other.flush_due(), other.flush_due_scan());
        assert_eq!(other.flush_due(), Some(90 + 500).min(Some(100 + 50)));
    }

    #[test]
    fn take_launch_shrinks_the_queue() {
        let mut b = batcher(8, 256, [10, 10]);
        b.push(0, Slo::Interactive, 0);
        b.push(1, Slo::Batch, 0);
        b.push(2, Slo::Batch, 0);
        assert_eq!(b.take_launch(2, 100).len(), 2);
        assert_eq!(b.len(), 1);
    }
}
