//! The serving [`Engine`] abstraction: one batched-inference backend
//! behind a uniform "submit batch → logits + timing" surface.
//!
//! Two implementations:
//!
//! * [`PjrtEngine`] — wraps [`crate::runtime::Runtime`] and the AOT
//!   artifact buckets (batch 8/4/2/1); real compute, wall-clock timing.
//! * [`SimEngine`] — wraps [`crate::accel::device::VirtualDevice`] plus
//!   the cycle model's per-unit schedule; deterministic pseudo-logits and
//!   model-time costs, so the whole serving stack (batcher, router, fleet
//!   experiments) runs without artifacts or a PJRT runtime.
//!
//! The batched-launch cost model in `SimEngine` mirrors the hardware
//! double-buffering: weights stream once per launch while compute scales
//! with the batch, i.e. per scheduling unit
//! `cycles(b) = max(b · compute, memory)` — which is exactly why batching
//! pays on this memory-bound accelerator.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::accel::control::Scheduler;
use crate::accel::device::VirtualDevice;
use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;
use crate::model::graph::WorkloadGraph;
use crate::runtime::{Runtime, Tensor};

/// Result of one batched launch.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Row-major logits: `batch × num_classes` values (padding rows
    /// included; callers slice out the rows they filled).
    pub logits: Vec<f32>,
    /// Engine-reported service time of the launch: device model time for
    /// [`SimEngine`], measured wall time for [`PjrtEngine`].
    pub compute: Duration,
}

/// A batched-inference backend. Object-safe; the continuous batcher owns
/// one per executor thread and [`super::router::Router`] dispatches over
/// `Vec<Box<dyn Engine>>`.
pub trait Engine {
    /// Human-readable identity (for reports).
    fn name(&self) -> String;

    /// Supported launch batch sizes, descending (the artifact buckets).
    fn batch_sizes(&self) -> &[usize];

    /// Flattened per-image element count.
    fn image_len(&self) -> usize;

    /// Logits per image.
    fn num_classes(&self) -> usize;

    /// Expected service time of one launch of `batch` images (used by
    /// load-balancing policies and admission heuristics; never blocks).
    fn service_estimate(&self, batch: usize) -> Duration;

    /// Execute one launch. `images.len()` must equal
    /// `batch * image_len()` and `batch` must be a supported size.
    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput>;
}

/// The artifact bucket sizes the AOT pipeline emits (and the sizes
/// `SimEngine` mirrors so both backends decompose identically).
pub const BUCKET_SIZES: [usize; 4] = [8, 4, 2, 1];

// ---------------------------------------------------------------------------
// SimEngine
// ---------------------------------------------------------------------------

/// Simulated card: cycle-model service times + deterministic pseudo-logits.
pub struct SimEngine {
    /// The underlying virtual card (busy/served bookkeeping in cycles).
    pub device: VirtualDevice,
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    sizes: Vec<usize>,
    /// Per scheduling unit: (compute + exposed-nonlinear, memory) cycles.
    units: Vec<(u64, u64)>,
    img_len: usize,
    /// Fraction of modelled service time actually slept per launch so the
    /// wall-clock batcher experiences realistic occupancy. 0 = never
    /// sleep (pure virtual time).
    time_scale: f64,
}

impl SimEngine {
    pub fn new(
        id: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        time_scale: f64,
    ) -> Self {
        let graph = WorkloadGraph::build(variant);
        let scheduler = Scheduler::new(cfg.clone());
        let units = scheduler
            .schedule(&graph)
            .iter()
            .map(|u| (u.compute() + u.nonlinear_exposed(), u.mem()))
            .collect();
        SimEngine {
            device: VirtualDevice::new(id, variant, cfg.clone()),
            variant,
            cfg,
            sizes: BUCKET_SIZES.to_vec(),
            units,
            img_len: variant.img_size * variant.img_size * variant.in_chans,
            time_scale,
        }
    }

    /// Modelled cycles for one launch of `batch` images: weights stream
    /// once, compute scales with the batch (see module docs).
    pub fn launch_cycles(&self, batch: usize) -> u64 {
        self.units
            .iter()
            .map(|&(cn, mem)| (batch as u64 * cn).max(mem))
            .sum()
    }

    fn launch_duration(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.cycles_to_ms(self.launch_cycles(batch)) / 1e3)
    }
}

/// Deterministic pseudo-logits for one image: a function of the image
/// contents only, so the same image classifies identically at every batch
/// size — the invariant the serving tests assert.
pub fn sim_logits(img: &[f32], classes: usize) -> Vec<f32> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in img {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (0..classes)
        .map(|c| {
            let mut z = h ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            z ^= z >> 33;
            z = z.wrapping_mul(0xff51_afd7_ed55_8ccd);
            z ^= z >> 33;
            ((z >> 11) as f64 / (1u64 << 53) as f64 * 8.0 - 4.0) as f32
        })
        .collect()
}

impl Engine for SimEngine {
    fn name(&self) -> String {
        format!("sim:{}#{}", self.variant.name, self.device.id)
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn image_len(&self) -> usize {
        self.img_len
    }

    fn num_classes(&self) -> usize {
        self.variant.num_classes
    }

    fn service_estimate(&self, batch: usize) -> Duration {
        self.launch_duration(batch)
    }

    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput> {
        anyhow::ensure!(
            self.sizes.contains(&batch),
            "unsupported batch {batch} (buckets {:?})",
            self.sizes
        );
        anyhow::ensure!(
            images.len() == batch * self.img_len,
            "input len {} != {} x {}",
            images.len(),
            batch,
            self.img_len
        );
        let cycles = self.launch_cycles(batch);
        let now = self.device.busy_until();
        self.device.enqueue_work(now, cycles, batch as u64);
        let compute = self.launch_duration(batch);
        if self.time_scale > 0.0 {
            std::thread::sleep(compute.mul_f64(self.time_scale));
        }
        let classes = self.variant.num_classes;
        let mut logits = Vec::with_capacity(batch * classes);
        for img in images.chunks_exact(self.img_len) {
            logits.extend(sim_logits(img, classes));
        }
        Ok(BatchOutput { logits, compute })
    }
}

// ---------------------------------------------------------------------------
// PjrtEngine
// ---------------------------------------------------------------------------

/// Real backend: the PJRT runtime plus the AOT serving artifacts. All
/// bucket engines are compiled at construction so serving latencies never
/// include compile time. PJRT handles are not assumed `Send`; construct
/// this inside the thread that will use it (see [`super::Server`]).
pub struct PjrtEngine {
    rt: Runtime,
    sizes: Vec<usize>,
    by_size: HashMap<usize, String>,
    img_len: usize,
    classes: usize,
    /// EWMA of measured service time per bucket.
    measured: HashMap<usize, Duration>,
}

impl PjrtEngine {
    pub fn new(dir: &Path) -> Result<PjrtEngine> {
        let rt = Runtime::new(dir)?;
        let serving = rt.serving_artifacts();
        anyhow::ensure!(!serving.is_empty(), "no serving artifacts in manifest");
        let mut sizes: Vec<usize> = serving.iter().map(|(b, _)| *b).collect();
        sizes.sort_by(|a, b| b.cmp(a)); // descending
        let by_size: HashMap<usize, String> = serving.into_iter().collect();
        // compile everything up front
        for name in by_size.values() {
            rt.engine(name)?;
        }
        let (&some_batch, some_name) = by_size.iter().next().context("no buckets")?;
        let info = &rt.engine(some_name)?.info;
        let img_len = info.inputs[0].numel() / some_batch;
        let classes = info.output.numel() / some_batch;
        Ok(PjrtEngine {
            rt,
            sizes,
            by_size,
            img_len,
            classes,
            measured: HashMap::new(),
        })
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> String {
        format!("pjrt:{}", self.rt.platform())
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn image_len(&self) -> usize {
        self.img_len
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn service_estimate(&self, batch: usize) -> Duration {
        // nearest supported bucket at or above the asked batch
        let bucket = self
            .sizes
            .iter()
            .copied()
            .filter(|&s| s >= batch)
            .min()
            .or_else(|| self.sizes.first().copied())
            .unwrap_or(1);
        self.measured
            .get(&bucket)
            .copied()
            .unwrap_or(Duration::from_millis(5))
    }

    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput> {
        let name = self
            .by_size
            .get(&batch)
            .with_context(|| format!("no artifact bucket for batch {batch}"))?;
        anyhow::ensure!(
            images.len() == batch * self.img_len,
            "input len {} != {} x {}",
            images.len(),
            batch,
            self.img_len
        );
        let eng = self.rt.engine(name)?;
        let t0 = Instant::now();
        let out = eng.run(&[Tensor::F32(images.to_vec())])?;
        let compute = t0.elapsed();
        let prev = self.measured.get(&batch).copied().unwrap_or(compute);
        self.measured
            .insert(batch, (prev * 3 + compute) / 4);
        Ok(BatchOutput {
            logits: out.as_f32()?.to_vec(),
            compute,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::MICRO;

    fn engine() -> SimEngine {
        SimEngine::new(0, &MICRO, AccelConfig::paper(), 0.0)
    }

    #[test]
    fn launch_cycles_match_simulator_at_batch_one() {
        use crate::accel::sim::Simulator;
        let e = engine();
        let r = Simulator::new(&MICRO, AccelConfig::paper()).simulate_inference();
        assert_eq!(e.launch_cycles(1), r.total_cycles);
    }

    #[test]
    fn batching_amortises_weight_streaming() {
        let e = engine();
        let c1 = e.launch_cycles(1);
        let c8 = e.launch_cycles(8);
        // a full launch costs less than 8 singles but at least 1 single
        assert!(c8 < 8 * c1, "c1={c1} c8={c8}");
        assert!(c8 >= c1);
        // per-image cost is monotone non-increasing in batch
        let per = |b: usize| e.launch_cycles(b) as f64 / b as f64;
        assert!(per(8) < per(4));
        assert!(per(4) < per(1));
    }

    #[test]
    fn sim_logits_deterministic_and_content_dependent() {
        let e = engine();
        let img: Vec<f32> = (0..e.image_len()).map(|i| (i % 7) as f32 * 0.1).collect();
        let a = sim_logits(&img, 10);
        let b = sim_logits(&img, 10);
        assert_eq!(a, b);
        let mut img2 = img.clone();
        img2[0] += 0.5;
        assert_ne!(a, sim_logits(&img2, 10));
        assert!(a.iter().all(|v| v.is_finite() && v.abs() <= 4.0));
    }

    #[test]
    fn run_batch_shapes_and_device_accounting() {
        let mut e = engine();
        let img_len = e.image_len();
        let images = vec![0.25f32; 2 * img_len];
        let out = e.run_batch(2, &images).unwrap();
        assert_eq!(out.logits.len(), 2 * e.num_classes());
        assert!(out.compute > Duration::ZERO);
        assert_eq!(e.device.served, 2);
        assert!(e.run_batch(3, &images).is_err()); // 3 is not a bucket
    }

    #[test]
    fn same_image_same_logits_across_batch_sizes() {
        let mut e = engine();
        let img_len = e.image_len();
        let img: Vec<f32> = (0..img_len).map(|i| (i % 13) as f32 * 0.05).collect();
        let solo = e.run_batch(1, &img).unwrap();
        let mut batch = img.clone();
        batch.extend(vec![0.0f32; 3 * img_len]);
        let quad = e.run_batch(4, &batch).unwrap();
        let classes = e.num_classes();
        assert_eq!(&solo.logits[..classes], &quad.logits[..classes]);
    }
}
