//! The serving [`Engine`] abstraction: one batched-inference backend
//! behind a uniform "submit batch → logits + timing" surface.
//!
//! Two implementations:
//!
//! * [`PjrtEngine`] — wraps [`crate::runtime::Runtime`] and the AOT
//!   artifact buckets (batch 8/4/2/1); real compute, wall-clock timing.
//! * [`SimEngine`] — wraps [`crate::accel::device::VirtualDevice`] plus
//!   the pipeline schedule IR; deterministic pseudo-logits and
//!   model-time costs, so the whole serving stack (batcher, router, fleet
//!   experiments) runs without artifacts or a PJRT runtime.
//!
//! Both take launch timing from the same place: the pipeline IR
//! ([`crate::accel::pipeline::PipelineSchedule`]). `SimEngine` queries
//! [`PipelineSchedule::launch_cycles`] directly — weights stream once per
//! launch while compute replays per image, which is exactly why batching
//! pays on this bandwidth-bound accelerator — and `PjrtEngine` warms its
//! cold-start [`Engine::service_estimate`] from a [`ServicePrior`] built
//! over the same schedule, replaced by an EWMA of measured launches as
//! they arrive.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::accel::device::VirtualDevice;
use crate::accel::pipeline::{CostTable, PipelineSchedule, Resource};
use crate::accel::power::{self, SpanBusy};
use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;
use crate::runtime::{Runtime, Tensor};

/// Result of one batched launch.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Row-major logits: `batch × num_classes` values (padding rows
    /// included; callers slice out the rows they filled).
    pub logits: Vec<f32>,
    /// Engine-reported service time of the launch: device model time for
    /// [`SimEngine`], measured wall time for [`PjrtEngine`].
    pub compute: Duration,
}

/// A batched-inference backend. Object-safe; the continuous batcher owns
/// one per executor thread and [`super::router::Router`] dispatches over
/// `Vec<Box<dyn Engine>>`. Engines are **fail-stop** under the fault
/// layer ([`super::fault`]): a launch either completes and its results
/// become observable at the finish cycle, or the card crashes first and
/// the whole launch is retracted — there are no partial-launch outputs,
/// and energy already booked for retracted work is never refunded.
pub trait Engine {
    /// Human-readable identity (for reports).
    fn name(&self) -> String;

    /// Supported launch batch sizes, descending (the artifact buckets).
    fn batch_sizes(&self) -> &[usize];

    /// Flattened per-image element count.
    fn image_len(&self) -> usize;

    /// Logits per image.
    fn num_classes(&self) -> usize;

    /// Card identity for per-card metrics (engine id; 0 when the backend
    /// has no meaningful device index).
    fn card_id(&self) -> usize {
        0
    }

    /// Expected service time of serving `batch` images (used by
    /// load-balancing policies and admission heuristics; never blocks).
    ///
    /// A `batch` that is not a supported bucket — in particular one
    /// *above* the largest bucket — must be priced as the multi-launch
    /// greedy decomposition the continuous batcher would actually run
    /// (`Σ` over [`super::decompose`]), not as a single clamped launch.
    fn service_estimate(&self, batch: usize) -> Duration;

    /// Steady-state (warm-queue) service time of one more batch-`batch`
    /// launch appended to a back-to-back launch stream: cross-launch
    /// weight prefetch hides the cold entry cost
    /// ([`crate::accel::pipeline::SequenceSchedule`]). Never above
    /// [`Self::service_estimate`]; backends without a warm model fall
    /// back to the cold estimate. The router prices *queued* work with
    /// this (a queued launch runs back-to-back behind the launch ahead
    /// of it) and keeps the cold estimate for launches that find the
    /// card idle.
    fn steady_estimate(&self, batch: usize) -> Duration {
        self.service_estimate(batch)
    }

    /// Cycle-domain fast path for virtual-time consumers: the cold
    /// estimate converted at `cycles_per_ms` virtual cycles per
    /// millisecond. The default round-trips [`Self::service_estimate`]
    /// exactly the way the fleet router always has
    /// (`(secs × 1e3 × cycles_per_ms).round()`), so overriding is an
    /// optimisation, never a semantic change; the router snapshots these
    /// per bucket so its per-arrival hot loop is pure `u64` arithmetic
    /// — no `Duration`/`f64` round-trip per price. The `Duration` API
    /// stays the contract for the wall-clock executor.
    fn service_estimate_cycles(&self, batch: usize, cycles_per_ms: f64) -> u64 {
        (self.service_estimate(batch).as_secs_f64() * 1e3 * cycles_per_ms).round() as u64
    }

    /// Cycle-domain counterpart of [`Self::steady_estimate`] (see
    /// [`Self::service_estimate_cycles`]).
    fn steady_estimate_cycles(&self, batch: usize, cycles_per_ms: f64) -> u64 {
        (self.steady_estimate(batch).as_secs_f64() * 1e3 * cycles_per_ms).round() as u64
    }

    /// Modelled energy of one *cold* batch-`batch` launch in integer
    /// microjoules: busy-fraction-weighted dynamic power plus static
    /// over the launch span ([`crate::accel::power::launch_energy_uj`]).
    /// Batches above the largest bucket are the decomposition's sum,
    /// like the time estimates. 0 means the backend has no energy model
    /// (a real PJRT card reports no power telemetry) — the router treats
    /// an unpriced fleet as all-equal and energy routing degenerates to
    /// the latency tie-break.
    fn launch_energy_uj(&self, _batch: usize) -> u64 {
        0
    }

    /// Warm (steady-state) per-launch energy in microjoules: the same
    /// busy cycles booked into the shorter warm span — more watts,
    /// strictly fewer joules. Falls back to the cold energy.
    fn steady_energy_uj(&self, batch: usize) -> u64 {
        self.launch_energy_uj(batch)
    }

    /// Cycles a power-gated card pays before its first launch computes:
    /// gating drops the resident weight window, so one stream window
    /// must land again
    /// ([`crate::accel::pipeline::PipelineSchedule::wakeup_fill_cycles`]).
    /// 0 = the backend models no gating.
    fn wakeup_cycles(&self) -> u64 {
        0
    }

    /// Idle (clocked but ungated) draw in integer microwatts — what
    /// power gating reclaims between launches. 0 = unmodelled.
    fn idle_power_uw(&self) -> u64 {
        0
    }

    /// Execute one launch. `images.len()` must equal
    /// `batch * image_len()` and `batch` must be a supported size.
    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput>;
}

/// The artifact bucket sizes the AOT pipeline emits (and the sizes
/// `SimEngine` mirrors so both backends decompose identically).
pub const BUCKET_SIZES: [usize; 4] = [8, 4, 2, 1];

// ---------------------------------------------------------------------------
// ServicePrior
// ---------------------------------------------------------------------------

/// Model-derived launch-time prior: what the pipeline schedule says a
/// batch-`b` launch costs, used to answer [`Engine::service_estimate`]
/// before any launch has been measured (the "cold start" the router and
/// batcher heuristics would otherwise guess at).
///
/// Since the shared-cost-table refactor this is a thin `Duration` view
/// over an `Arc<`[`CostTable`]`>`: fleet builders construct the table
/// once per variant and every prior/engine/card of that variant reads
/// the same memoized cold/warm cycles (the sequence-convergence loop
/// runs once per bucket, never on a per-arrival path).
#[derive(Debug, Clone)]
pub struct ServicePrior {
    table: Arc<CostTable>,
}

impl ServicePrior {
    pub fn from_schedule(schedule: PipelineSchedule) -> Self {
        Self::from_table(Arc::new(CostTable::from_schedule(schedule, &BUCKET_SIZES)))
    }

    pub fn for_variant(variant: &SwinVariant, cfg: AccelConfig) -> Self {
        Self::from_table(Arc::new(CostTable::for_variant(variant, cfg, &BUCKET_SIZES)))
    }

    /// Share an already-built cost table (no re-lowering, no
    /// re-convergence — the fleet constructor path).
    pub fn from_table(table: Arc<CostTable>) -> Self {
        ServicePrior { table }
    }

    /// The shared cost table this prior reads.
    pub fn cost_table(&self) -> &Arc<CostTable> {
        &self.table
    }

    /// Extend the memoized buckets to an engine's actual bucket ladder
    /// (the artifact manifest need not use [`BUCKET_SIZES`]); keeps the
    /// sequence-convergence loop off the per-arrival pricing path for
    /// every bucket the engine will actually ask about.
    pub fn with_buckets(mut self, sizes: &[usize]) -> Self {
        if sizes.iter().any(|&b| self.table.buckets().all(|have| have != b.max(1))) {
            self.table = Arc::new(self.table.with_buckets(sizes));
        }
        self
    }

    /// Modelled service time of one batch-`batch` launch.
    pub fn estimate(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.table.cold_ms(batch) / 1e3)
    }

    /// Modelled steady-state (warm-queue) service time of one
    /// batch-`batch` launch (see
    /// [`PipelineSchedule::steady_launch_cycles`]; memoized per bucket).
    pub fn steady_estimate(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.table.warm_ms(batch) / 1e3)
    }
}

// ---------------------------------------------------------------------------
// SimEngine
// ---------------------------------------------------------------------------

/// Simulated card: pipeline-schedule service times + deterministic
/// pseudo-logits.
pub struct SimEngine {
    /// The underlying virtual card (busy/served bookkeeping in cycles;
    /// shares the lowered [`PipelineSchedule`] with the cost table).
    pub device: VirtualDevice,
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    sizes: Vec<usize>,
    img_len: usize,
    /// Shared cold/warm launch-cost table — one `Arc` per variant ×
    /// config in a fleet ([`SimEngine::with_table`]); every estimate is
    /// a memoized lookup, never a fresh placement or convergence loop.
    table: Arc<CostTable>,
    /// Fraction of modelled service time actually slept per launch so the
    /// wall-clock batcher experiences realistic occupancy. 0 = never
    /// sleep (pure virtual time).
    time_scale: f64,
}

impl SimEngine {
    pub fn new(
        id: usize,
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        time_scale: f64,
    ) -> Self {
        let table = Arc::new(CostTable::for_variant(variant, cfg, &BUCKET_SIZES));
        Self::with_table(id, variant, table, time_scale)
    }

    /// Build a card over an already-built shared cost table (fleet
    /// constructors build one table per variant and hand each card a
    /// clone of the `Arc` — an N-card homogeneous fleet lowers the
    /// schedule and converges the warm costs once, not N times).
    pub fn with_table(
        id: usize,
        variant: &'static SwinVariant,
        table: Arc<CostTable>,
        time_scale: f64,
    ) -> Self {
        let device = VirtualDevice::with_schedule(id, variant, table.share_schedule());
        SimEngine {
            device,
            variant,
            cfg: table.schedule().cfg.clone(),
            sizes: BUCKET_SIZES.to_vec(),
            img_len: variant.img_size * variant.img_size * variant.in_chans,
            table,
            time_scale,
        }
    }

    /// The shared cost table this engine prices launches from.
    pub fn cost_table(&self) -> &Arc<CostTable> {
        &self.table
    }

    /// Modelled cycles for one launch of `batch` images, from the shared
    /// cost table (weights stream once per launch, compute replays per
    /// image).
    pub fn launch_cycles(&self, batch: usize) -> u64 {
        self.table.cold_cycles(batch)
    }

    /// Steady-state (warm-queue) cycles of one more batch-`batch` launch
    /// in a back-to-back stream (memoized per bucket in the table).
    pub fn steady_launch_cycles(&self, batch: usize) -> u64 {
        self.table.warm_cycles(batch)
    }

    fn launch_duration(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.cycles_to_ms(self.launch_cycles(batch)) / 1e3)
    }

    fn steady_duration(&self, batch: usize) -> Duration {
        Duration::from_secs_f64(self.cfg.cycles_to_ms(self.steady_launch_cycles(batch)) / 1e3)
    }

    /// Busy cycles of one batch-`batch` launch, per engine: compute and
    /// nonlinear work replay per image while the weight stream is shared
    /// across the batch ([`PipelineSchedule::busy_batched`]).
    fn launch_busy(&self, batch: usize) -> SpanBusy {
        let s = self.table.schedule();
        SpanBusy {
            mmu: s.busy_batched(Resource::Mmu, batch),
            scu: s.busy_batched(Resource::Scu, batch),
            gcu: s.busy_batched(Resource::Gcu, batch),
            mru: s.busy_batched(Resource::Mru, batch),
        }
    }

    /// Energy of one bucket-sized launch in µJ: its busy cycles booked
    /// into the cold (idle-entry) or warm (back-to-back) span.
    fn energy_uj_one(&self, batch: usize, warm: bool) -> u64 {
        let span = if warm {
            self.steady_launch_cycles(batch)
        } else {
            self.launch_cycles(batch)
        };
        power::launch_energy_uj(self.variant, &self.cfg, self.launch_busy(batch), span)
    }
}

/// Deterministic pseudo-logits for one image: a function of the image
/// contents only, so the same image classifies identically at every batch
/// size — the invariant the serving tests assert.
pub fn sim_logits(img: &[f32], classes: usize) -> Vec<f32> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in img {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (0..classes)
        .map(|c| {
            let mut z = h ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            z ^= z >> 33;
            z = z.wrapping_mul(0xff51_afd7_ed55_8ccd);
            z ^= z >> 33;
            ((z >> 11) as f64 / (1u64 << 53) as f64 * 8.0 - 4.0) as f32
        })
        .collect()
}

impl Engine for SimEngine {
    fn name(&self) -> String {
        format!("sim:{}#{}", self.variant.name, self.device.id)
    }

    fn card_id(&self) -> usize {
        self.device.id
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn image_len(&self) -> usize {
        self.img_len
    }

    fn num_classes(&self) -> usize {
        self.variant.num_classes
    }

    fn service_estimate(&self, batch: usize) -> Duration {
        // price the multi-launch plan the batcher would run: exact for
        // bucket sizes (decompose(b) = [b]) and a faithful sum above the
        // largest bucket (regression: a batch of 16 used to be priced as
        // one batch-8 launch)
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, b| acc + self.launch_duration(b))
    }

    fn steady_estimate(&self, batch: usize) -> Duration {
        // same decomposition, each launch at its warm steady-state cost
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, b| acc + self.steady_duration(b))
    }

    fn launch_energy_uj(&self, batch: usize) -> u64 {
        // same multi-launch decomposition as the time estimates: energy
        // of N launches is the sum of N launch energies
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .map(|b| self.energy_uj_one(b, false))
            .sum()
    }

    fn steady_energy_uj(&self, batch: usize) -> u64 {
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .map(|b| self.energy_uj_one(b, true))
            .sum()
    }

    fn wakeup_cycles(&self) -> u64 {
        self.table.schedule().wakeup_fill_cycles()
    }

    fn idle_power_uw(&self) -> u64 {
        power::idle_power_uw(self.variant, &self.cfg)
    }

    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput> {
        anyhow::ensure!(
            self.sizes.contains(&batch),
            "unsupported batch {batch} (buckets {:?})",
            self.sizes
        );
        anyhow::ensure!(
            images.len() == batch * self.img_len,
            "input len {} != {} x {}",
            images.len(),
            batch,
            self.img_len
        );
        let cycles = self.launch_cycles(batch);
        let now = self.device.busy_until();
        self.device.enqueue_work(now, cycles, batch as u64);
        let compute = self.launch_duration(batch);
        if self.time_scale > 0.0 {
            std::thread::sleep(compute.mul_f64(self.time_scale));
        }
        let classes = self.variant.num_classes;
        let mut logits = Vec::with_capacity(batch * classes);
        for img in images.chunks_exact(self.img_len) {
            logits.extend(sim_logits(img, classes));
        }
        Ok(BatchOutput { logits, compute })
    }
}

// ---------------------------------------------------------------------------
// PjrtEngine
// ---------------------------------------------------------------------------

/// Real backend: the PJRT runtime plus the AOT serving artifacts. All
/// bucket engines are compiled at construction so serving latencies never
/// include compile time. PJRT handles are not assumed `Send`; construct
/// this inside the thread that will use it (see [`super::Server`]).
///
/// Service estimates: an EWMA of measured launch times per bucket, warmed
/// before the first launch by the cycle model ([`ServicePrior`]) when the
/// artifact manifest names its Swin variant.
pub struct PjrtEngine {
    rt: Runtime,
    sizes: Vec<usize>,
    by_size: HashMap<usize, String>,
    img_len: usize,
    classes: usize,
    /// EWMA of measured service time per bucket.
    measured: HashMap<usize, Duration>,
    /// Cycle-model fallback for buckets never launched.
    prior: Option<ServicePrior>,
}

impl PjrtEngine {
    pub fn new(dir: &Path) -> Result<PjrtEngine> {
        let rt = Runtime::new(dir)?;
        let serving = rt.serving_artifacts();
        anyhow::ensure!(!serving.is_empty(), "no serving artifacts in manifest");
        let mut sizes: Vec<usize> = serving.iter().map(|(b, _)| *b).collect();
        sizes.sort_by(|a, b| b.cmp(a)); // descending
        let by_size: HashMap<usize, String> = serving.into_iter().collect();
        // compile everything up front
        for name in by_size.values() {
            rt.engine(name)?;
        }
        let (&some_batch, some_name) = by_size.iter().next().context("no buckets")?;
        let info = &rt.engine(some_name)?.info;
        let img_len = info.inputs[0].numel() / some_batch;
        let classes = info.output.numel() / some_batch;
        // warm the cold-start estimate from the cycle model when the
        // manifest says which variant these artifacts were compiled from
        let prior = by_size
            .values()
            .filter_map(|name| rt.manifest.artifacts.get(name))
            .find_map(|a| a.variant.as_deref())
            .and_then(SwinVariant::by_name)
            .map(|v| ServicePrior::for_variant(v, AccelConfig::paper()).with_buckets(&sizes));
        Ok(PjrtEngine {
            rt,
            sizes,
            by_size,
            img_len,
            classes,
            measured: HashMap::new(),
            prior,
        })
    }

    /// Override the cold-start prior (e.g. a non-paper configuration).
    pub fn with_prior(mut self, prior: ServicePrior) -> Self {
        self.prior = Some(prior.with_buckets(&self.sizes));
        self
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> String {
        format!("pjrt:{}", self.rt.platform())
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.sizes
    }

    fn image_len(&self) -> usize {
        self.img_len
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn service_estimate(&self, batch: usize) -> Duration {
        // price the greedy multi-launch decomposition the batcher would
        // run (a batch above the largest bucket is several launches, not
        // one clamped largest-bucket launch); each bucket is priced by
        // its measured EWMA, falling back to the cycle-model prior
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, bucket| {
                acc + self.measured.get(&bucket).copied().unwrap_or_else(|| {
                    self.prior
                        .as_ref()
                        .map(|p| p.estimate(bucket))
                        .unwrap_or(Duration::from_millis(5))
                })
            })
    }

    fn steady_estimate(&self, batch: usize) -> Duration {
        // measured launches already reflect real queue conditions; only
        // the cycle-model fallback distinguishes warm from cold
        super::decompose(batch.max(1), &self.sizes)
            .into_iter()
            .fold(Duration::ZERO, |acc, bucket| {
                acc + self.measured.get(&bucket).copied().unwrap_or_else(|| {
                    self.prior
                        .as_ref()
                        .map(|p| p.steady_estimate(bucket))
                        .unwrap_or(Duration::from_millis(5))
                })
            })
    }

    fn run_batch(&mut self, batch: usize, images: &[f32]) -> Result<BatchOutput> {
        let name = self
            .by_size
            .get(&batch)
            .with_context(|| format!("no artifact bucket for batch {batch}"))?;
        anyhow::ensure!(
            images.len() == batch * self.img_len,
            "input len {} != {} x {}",
            images.len(),
            batch,
            self.img_len
        );
        let eng = self.rt.engine(name)?;
        let t0 = Instant::now();
        let out = eng.run(&[Tensor::F32(images.to_vec())])?;
        let compute = t0.elapsed();
        let prev = self.measured.get(&batch).copied().unwrap_or(compute);
        self.measured
            .insert(batch, (prev * 3 + compute) / 4);
        Ok(BatchOutput {
            logits: out.as_f32()?.to_vec(),
            compute,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::{MICRO, TINY};

    fn engine() -> SimEngine {
        SimEngine::new(0, &MICRO, AccelConfig::paper(), 0.0)
    }

    #[test]
    fn launch_cycles_match_simulator_at_batch_one() {
        use crate::accel::sim::Simulator;
        let e = engine();
        let r = Simulator::new(&MICRO, AccelConfig::paper()).simulate_inference();
        assert_eq!(e.launch_cycles(1), r.total_cycles);
    }

    #[test]
    fn batching_amortises_weight_streaming() {
        let e = engine();
        let c1 = e.launch_cycles(1);
        let c8 = e.launch_cycles(8);
        // a full launch costs less than 8 singles but at least 1 single
        assert!(c8 < 8 * c1, "c1={c1} c8={c8}");
        assert!(c8 >= c1);
        // per-image cost is monotone non-increasing in batch
        let per = |b: usize| e.launch_cycles(b) as f64 / b as f64;
        assert!(per(8) < per(4));
        assert!(per(4) < per(1));
    }

    #[test]
    fn prior_warms_cold_start_within_2x_of_bandwidth_bound() {
        // the ROADMAP item: before any launch is measured, the pjrt
        // backend's estimate must come from the cycle model. The prior
        // and SimEngine read the same schedule, so comparing them to
        // each other would be vacuous — instead check the prior against
        // an *independently* computed bandwidth bound (total streamed
        // bytes over the effective AXI bandwidth): a unit-conversion or
        // batching mistake in the prior cannot cancel out of this.
        use crate::model::graph::WorkloadGraph;
        for v in [&MICRO, &TINY] {
            let cfg = AccelConfig::paper();
            let g = WorkloadGraph::build(v);
            let bytes = (g.total_weight_bytes() + g.total_activation_bytes()) as f64;
            let floor_cycles = (bytes / cfg.effective_bw()).ceil() as u64;
            let floor_s = cfg.cycles_to_ms(floor_cycles) / 1e3;
            let p = ServicePrior::for_variant(v, cfg.clone())
                .estimate(1)
                .as_secs_f64();
            assert!(p >= floor_s * 0.999, "{}: {p} under bound {floor_s}", v.name);
            assert!(p <= 2.0 * floor_s, "{}: {p} not within 2x of {floor_s}", v.name);
            // …and the serving wiring agrees with the same schedule
            let sim = SimEngine::new(0, v, cfg.clone(), 0.0);
            for b in BUCKET_SIZES {
                assert_eq!(
                    ServicePrior::for_variant(v, cfg.clone()).estimate(b),
                    sim.service_estimate(b),
                    "{} b={b}",
                    v.name
                );
            }
        }
    }

    #[test]
    fn service_estimate_above_largest_bucket_sums_the_decomposition() {
        // regression: a batch above the largest artifact bucket used to
        // be priced as one clamped largest-bucket launch; it must cost
        // the multi-launch decomposition the batcher actually runs
        let e = engine();
        let est = |b: usize| e.service_estimate(b);
        assert_eq!(est(16), est(8) + est(8));
        assert_eq!(est(13), est(8) + est(4) + est(1));
        assert_eq!(est(24), est(8) + est(8) + est(8));
        assert!(est(16) > est(8), "16 images cannot be as cheap as 8");
        // within-bucket asks are still a single launch (monotone in b)
        assert!(est(8) < est(16));
        assert!(est(1) <= est(2));
    }

    #[test]
    fn steady_estimate_warm_below_cold_and_consistent_with_prior() {
        use crate::model::config::{BASE, SMALL, TINY};
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            let cfg = AccelConfig::paper();
            let e = SimEngine::new(0, v, cfg.clone(), 0.0);
            let prior = ServicePrior::for_variant(v, cfg.clone());
            for b in BUCKET_SIZES {
                // warm never above cold…
                assert!(e.steady_estimate(b) <= e.service_estimate(b), "{} b={b}", v.name);
                // …and the prior's warm estimate is the same schedule
                assert_eq!(prior.steady_estimate(b), e.steady_estimate(b), "{} b={b}", v.name);
            }
            // strictly below at the full bucket (the warm entry skips the
            // cold window fill)
            assert!(
                e.steady_estimate(8) < e.service_estimate(8),
                "{}: warm {:?} !< cold {:?}",
                v.name,
                e.steady_estimate(8),
                e.service_estimate(8)
            );
            // with cross-launch prefetch disabled the two coincide
            let e = SimEngine::new(0, v, cfg.interlaunch(false), 0.0);
            for b in BUCKET_SIZES {
                assert_eq!(e.steady_estimate(b), e.service_estimate(b), "{} b={b}", v.name);
            }
        }
    }

    #[test]
    fn steady_estimate_above_largest_bucket_sums_the_decomposition() {
        let e = engine();
        let est = |b: usize| e.steady_estimate(b);
        assert_eq!(est(16), est(8) + est(8));
        assert_eq!(est(13), est(8) + est(4) + est(1));
    }

    #[test]
    fn launch_energy_books_busy_cycles_into_the_launch_span() {
        // the engine's µJ figure must be exactly the power model's
        // busy-over-span energy — no second energy formula hiding in the
        // serving layer — and the physics must come out right: the warm
        // span is never longer at identical busy work, so warm launches
        // burn no more joules per launch (strictly fewer at batch 8,
        // where the warm entry skips the cold window fill; at small
        // batches the stream-bound spans coincide and so do the
        // energies), and batching amortises the weight stream so
        // per-image energy falls with batch size.
        use crate::accel::nonlinear::NlDesign;
        use crate::model::config::{BASE, SMALL, TINY};
        for v in [&MICRO, &TINY, &SMALL, &BASE] {
            for design in [NlDesign::Baseline, NlDesign::Quark, NlDesign::Peano] {
                let cfg = AccelConfig::paper().nonlinear(design);
                let e = SimEngine::new(0, v, cfg.clone(), 0.0);
                for b in BUCKET_SIZES {
                    let uj = e.launch_energy_uj(b);
                    let expect = power::launch_energy_uj(
                        v,
                        &cfg,
                        e.launch_busy(b),
                        e.launch_cycles(b),
                    );
                    assert_eq!(uj, expect, "{} {design:?} b={b}", v.name);
                    assert!(uj > 0, "{} b={b}: zero energy", v.name);
                    assert!(
                        e.steady_energy_uj(b) <= uj,
                        "{} {design:?} b={b}: warm energy {} > cold {}",
                        v.name,
                        e.steady_energy_uj(b),
                        uj
                    );
                }
                // at batch 8 the warm entry skips the cold window fill,
                // so the warm launch is strictly cheaper (mirrors
                // steady_cost_below_cold_when_warm_and_equal_when_disabled)
                assert!(
                    e.steady_energy_uj(8) < e.launch_energy_uj(8),
                    "{} {design:?}: warm energy {} !< cold {}",
                    v.name,
                    e.steady_energy_uj(8),
                    e.launch_energy_uj(8)
                );
                // per-image energy is monotone non-increasing in batch
                let per = |b: usize| e.launch_energy_uj(b) as f64 / b as f64;
                assert!(per(8) < per(4), "{} {design:?}", v.name);
                assert!(per(4) < per(1), "{} {design:?}", v.name);
                // above the largest bucket: the decomposition's sum
                assert_eq!(
                    e.launch_energy_uj(16),
                    e.launch_energy_uj(8) + e.launch_energy_uj(8)
                );
                assert_eq!(
                    e.launch_energy_uj(13),
                    e.launch_energy_uj(8) + e.launch_energy_uj(4) + e.launch_energy_uj(1)
                );
            }
        }
    }

    #[test]
    fn quark_launches_cost_fewer_joules_than_baseline() {
        // QUARK's whole point: one shared exp/gelu pipe halves the
        // nonlinear-unit fabric, and with the per-window arbitration fix
        // its launch cycles match baseline whenever the engines never
        // co-live — so the energy per launch must come out strictly lower.
        use crate::accel::nonlinear::NlDesign;
        use crate::model::config::TINY;
        let base = SimEngine::new(0, &TINY, AccelConfig::paper(), 0.0);
        let quark =
            SimEngine::new(0, &TINY, AccelConfig::paper().nonlinear(NlDesign::Quark), 0.0);
        assert_eq!(base.launch_cycles(1), quark.launch_cycles(1));
        for b in BUCKET_SIZES {
            assert!(
                quark.launch_energy_uj(b) < base.launch_energy_uj(b),
                "b={b}: quark {} !< baseline {}",
                quark.launch_energy_uj(b),
                base.launch_energy_uj(b)
            );
        }
    }

    #[test]
    fn wakeup_and_idle_power_bound_the_gating_tradeoff() {
        let e = engine();
        // waking a gated card costs a real but sub-launch stream fill
        let wake = e.wakeup_cycles();
        assert!(wake > 0);
        assert!(wake < e.launch_cycles(1), "wake {wake} >= a full launch");
        // idle draw: at least the static floor, below the loaded draw
        let idle_uw = e.idle_power_uw();
        assert!(idle_uw >= 4_000_000, "idle {idle_uw} µW under static floor");
        let span = e.launch_cycles(1);
        let loaded_w = power::launch_energy_j(&MICRO, &AccelConfig::paper(), e.launch_busy(1), span)
            / (span as f64 / (AccelConfig::paper().freq_mhz * 1e6));
        assert!((idle_uw as f64) < loaded_w * 1e6, "idle not below loaded draw");
        // the default trait impls stay inert for backends without a model
        struct NoModel;
        impl Engine for NoModel {
            fn name(&self) -> String {
                "none".into()
            }
            fn batch_sizes(&self) -> &[usize] {
                &[1]
            }
            fn image_len(&self) -> usize {
                1
            }
            fn num_classes(&self) -> usize {
                1
            }
            fn service_estimate(&self, _batch: usize) -> Duration {
                Duration::from_millis(1)
            }
            fn run_batch(&mut self, _batch: usize, _images: &[f32]) -> Result<BatchOutput> {
                anyhow::bail!("unused")
            }
        }
        let n = NoModel;
        assert_eq!(n.launch_energy_uj(8), 0);
        assert_eq!(n.steady_energy_uj(8), 0);
        assert_eq!(n.wakeup_cycles(), 0);
        assert_eq!(n.idle_power_uw(), 0);
    }

    #[test]
    fn sim_logits_deterministic_and_content_dependent() {
        let e = engine();
        let img: Vec<f32> = (0..e.image_len()).map(|i| (i % 7) as f32 * 0.1).collect();
        let a = sim_logits(&img, 10);
        let b = sim_logits(&img, 10);
        assert_eq!(a, b);
        let mut img2 = img.clone();
        img2[0] += 0.5;
        assert_ne!(a, sim_logits(&img2, 10));
        assert!(a.iter().all(|v| v.is_finite() && v.abs() <= 4.0));
    }

    #[test]
    fn run_batch_shapes_and_device_accounting() {
        let mut e = engine();
        let img_len = e.image_len();
        let images = vec![0.25f32; 2 * img_len];
        let out = e.run_batch(2, &images).unwrap();
        assert_eq!(out.logits.len(), 2 * e.num_classes());
        assert!(out.compute > Duration::ZERO);
        assert_eq!(e.device.served, 2);
        assert!(e.run_batch(3, &images).is_err()); // 3 is not a bucket
    }

    #[test]
    fn same_image_same_logits_across_batch_sizes() {
        let mut e = engine();
        let img_len = e.image_len();
        let img: Vec<f32> = (0..img_len).map(|i| (i % 13) as f32 * 0.05).collect();
        let solo = e.run_batch(1, &img).unwrap();
        let mut batch = img.clone();
        batch.extend(vec![0.0f32; 3 * img_len]);
        let quad = e.run_batch(4, &batch).unwrap();
        let classes = e.num_classes();
        assert_eq!(&solo.logits[..classes], &quad.logits[..classes]);
    }
}
