//! Deterministic fault injection for the serving fleet.
//!
//! A [`FaultPlan`] schedules per-card events on the router's virtual
//! timeline (cycles): fail-stop crashes, transient slowdowns, and
//! planned membership changes (join/leave). Plans are plain data — the
//! router tiers interpret them — and random plans are derived from a
//! [`CounterRng`](crate::util::prng::CounterRng) substream per card, so
//! a plan is a pure function of `(seed, card)` and sharded runs stay
//! bit-identical across thread counts (the PR-7 determinism contract).
//!
//! ## Fault model
//!
//! - **Crashes are fail-stop.** A card that crashes at `T` produces no
//!   results with `finish > T`; there are no partial-launch results and
//!   no byzantine outputs. In-flight work is lost and re-enters routing
//!   with its original enqueue tick, bounded by the plan's per-request
//!   retry budget.
//! - **Energy already spent is not refunded** on a crash — the joules
//!   went into the card even though the answers were lost.
//! - **Degrade is multiplicative**: while active, every launch costs
//!   `factor_pct/100 ×` its normal cycles, and the load signals price
//!   the card accordingly so JSQ/Backlog/Energy see the survivor
//!   fleet's true capacity.
//! - **Leave is graceful**: no new admissions, queued work drains back
//!   through the normal assignment path exactly once, in-flight work
//!   completes, then the card is down.
//! - **Join** brings a card up at `at`; until then it is down and
//!   unpickable. A joining card's first launch is cold as usual.

use crate::util::prng::CounterRng;

/// Cycles per millisecond of virtual time (mirrors `router::CYCLES_PER_MS`).
const CYCLES_PER_MS: f64 = 200_000.0;

/// Convert a millisecond offset on the virtual timeline to cycles.
pub fn ms_to_cycles(ms: f64) -> u64 {
    (ms * CYCLES_PER_MS).round() as u64
}

/// Health of one card as seen by the router's pick path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CardHealth {
    /// Serving normally; pickable.
    Up,
    /// Serving with a launch-cost multiplier; pickable but priced up.
    Degraded,
    /// Leaving: no new admissions, in-flight work completing.
    Draining,
    /// Crashed, left, or not yet joined; unpickable.
    Down,
}

impl CardHealth {
    /// Whether the pick path may assign new work to this card.
    pub fn pickable(self) -> bool {
        matches!(self, CardHealth::Up | CardHealth::Degraded)
    }
}

/// One scheduled event on a card's timeline. All times are cycles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// Fail-stop at `at`: in-flight results with `finish > at` are lost.
    Crash { at: u64 },
    /// From `at` until `until`, launches cost `factor_pct/100 ×` cycles.
    Degrade { at: u64, factor_pct: u64, until: u64 },
    /// Card becomes pickable at `at` (it is down before its first Join).
    Join { at: u64 },
    /// Graceful removal at `at`: drain queue, finish in-flight, go down.
    Leave { at: u64 },
}

impl FaultEvent {
    /// The cycle at which the event fires.
    pub fn at(&self) -> u64 {
        match *self {
            FaultEvent::Crash { at }
            | FaultEvent::Degrade { at, .. }
            | FaultEvent::Join { at }
            | FaultEvent::Leave { at } => at,
        }
    }
}

/// A deterministic per-card fault schedule plus the retry policy.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Per-card events, each list sorted by firing cycle.
    pub events: Vec<Vec<FaultEvent>>,
    /// How many times one request may be redispatched after crash loss
    /// before it is counted as lost. Queue redistribution on a graceful
    /// leave does not consume budget.
    pub retry_budget: u32,
}

impl FaultPlan {
    /// An empty plan for `cards` cards (no faults, default retry budget).
    pub fn none(cards: usize) -> Self {
        FaultPlan { events: vec![Vec::new(); cards], retry_budget: 3 }
    }

    /// True when no card has any scheduled event.
    pub fn is_empty(&self) -> bool {
        self.events.iter().all(|v| v.is_empty())
    }

    /// Number of cards the plan covers.
    pub fn cards(&self) -> usize {
        self.events.len()
    }

    /// Append `ev` to `card`'s schedule, keeping the list sorted by time.
    pub fn push(&mut self, card: usize, ev: FaultEvent) {
        let list = &mut self.events[card];
        let pos = list.partition_point(|e| e.at() <= ev.at());
        list.insert(pos, ev);
    }

    /// Health of `card` before any event fires: down if its first event
    /// is a `Join` (the card has not joined the fleet yet), up otherwise.
    pub fn initial_health(&self, card: usize) -> CardHealth {
        match self.events[card].first() {
            Some(FaultEvent::Join { .. }) => CardHealth::Down,
            _ => CardHealth::Up,
        }
    }

    /// The sub-plan covering cards `lo..lo+n`, with indices localized to
    /// the sub-range (for per-shard routers).
    pub fn subplan(&self, lo: usize, n: usize) -> Self {
        FaultPlan {
            events: self.events[lo..lo + n].to_vec(),
            retry_budget: self.retry_budget,
        }
    }

    /// A seeded random plan: a pure function of `(seed, card)` via a
    /// `CounterRng` substream per card, so it is identical no matter how
    /// the fleet is sharded or threaded. At most one event per card;
    /// roughly half the cards stay fault-free. Times land inside
    /// `[horizon/8, 7·horizon/8]` so faults interact with live traffic.
    pub fn random(seed: u64, cards: usize, horizon_cycles: u64, retry_budget: u32) -> Self {
        let root = CounterRng::new(seed);
        let horizon = horizon_cycles.max(8);
        let mut plan = FaultPlan { events: vec![Vec::new(); cards], retry_budget };
        for card in 0..cards {
            let s = root.stream(card as u64);
            if s.nth(0) % 2 == 0 {
                continue; // fault-free card
            }
            let span = horizon - horizon / 4;
            let at = horizon / 8 + s.nth(1) % span.max(1);
            let ev = match s.nth(2) % 10 {
                0..=3 => {
                    // Slowdown of 1.5×–4.0× for up to a quarter horizon.
                    let factor_pct = 150 + s.nth(3) % 251;
                    let until = at + 1 + s.nth(4) % (horizon / 4).max(1);
                    FaultEvent::Degrade { at, factor_pct, until }
                }
                4..=6 => FaultEvent::Crash { at },
                _ => FaultEvent::Leave { at },
            };
            plan.events[card].push(ev);
        }
        plan
    }

    /// Parse a CLI fault spec against a fleet of `cards` cards.
    ///
    /// Grammar (times in virtual-time milliseconds):
    ///
    /// - `none` — empty plan
    /// - `rand:SEED:BUDGET` — seeded random plan ([`FaultPlan::random`]
    ///   with a 2 s horizon)
    /// - semicolon-joined events:
    ///   `crash:CARD:AT_MS` | `degrade:CARD:AT_MS:FACTOR_PCT:UNTIL_MS` |
    ///   `leave:CARD:AT_MS` | `join:CARD:AT_MS`
    pub fn parse(spec: &str, cards: usize) -> Result<Self, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan::none(cards));
        }
        if let Some(rest) = spec.strip_prefix("rand:") {
            let mut it = rest.split(':');
            let seed: u64 = it
                .next()
                .and_then(|s| s.parse().ok())
                .ok_or_else(|| format!("bad rand seed in `{spec}`"))?;
            let budget: u32 = match it.next() {
                Some(s) => s.parse().map_err(|_| format!("bad rand budget in `{spec}`"))?,
                None => 3,
            };
            return Ok(FaultPlan::random(seed, cards, ms_to_cycles(2000.0), budget));
        }
        let mut plan = FaultPlan::none(cards);
        for part in spec.split(';').filter(|p| !p.trim().is_empty()) {
            let fields: Vec<&str> = part.trim().split(':').collect();
            let bad = || format!("bad fault event `{part}`");
            let card: usize = fields.get(1).and_then(|s| s.parse().ok()).ok_or_else(bad)?;
            if card >= cards {
                return Err(format!("card {card} out of range (fleet has {cards})"));
            }
            let at_ms: f64 = fields.get(2).and_then(|s| s.parse().ok()).ok_or_else(bad)?;
            let at = ms_to_cycles(at_ms);
            let ev = match fields[0] {
                "crash" if fields.len() == 3 => FaultEvent::Crash { at },
                "leave" if fields.len() == 3 => FaultEvent::Leave { at },
                "join" if fields.len() == 3 => FaultEvent::Join { at },
                "degrade" if fields.len() == 5 => {
                    let factor_pct: u64 =
                        fields[3].parse().map_err(|_| bad())?;
                    if factor_pct < 100 {
                        return Err(format!("degrade factor must be >= 100, got {factor_pct}"));
                    }
                    let until_ms: f64 = fields[4].parse().map_err(|_| bad())?;
                    FaultEvent::Degrade { at, factor_pct, until: ms_to_cycles(until_ms) }
                }
                _ => return Err(bad()),
            };
            plan.push(card, ev);
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_empty_and_up() {
        let p = FaultPlan::none(4);
        assert!(p.is_empty());
        assert_eq!(p.cards(), 4);
        for c in 0..4 {
            assert_eq!(p.initial_health(c), CardHealth::Up);
        }
    }

    #[test]
    fn push_keeps_events_sorted() {
        let mut p = FaultPlan::none(1);
        p.push(0, FaultEvent::Crash { at: 500 });
        p.push(0, FaultEvent::Leave { at: 100 });
        p.push(0, FaultEvent::Join { at: 300 });
        let ats: Vec<u64> = p.events[0].iter().map(|e| e.at()).collect();
        assert_eq!(ats, vec![100, 300, 500]);
    }

    #[test]
    fn join_first_means_initially_down() {
        let mut p = FaultPlan::none(2);
        p.push(1, FaultEvent::Join { at: 1000 });
        assert_eq!(p.initial_health(0), CardHealth::Up);
        assert_eq!(p.initial_health(1), CardHealth::Down);
    }

    #[test]
    fn random_is_deterministic_and_shard_invariant() {
        let a = FaultPlan::random(42, 8, 1_000_000, 3);
        let b = FaultPlan::random(42, 8, 1_000_000, 3);
        assert_eq!(a, b);
        // Per-card substreams: the global plan restricted to a shard's
        // range equals the shard's subplan — the property the sharded
        // router relies on.
        let sub = a.subplan(4, 4);
        assert_eq!(&a.events[4..8], &sub.events[..]);
        // Different seeds move at least one card's schedule.
        let c = FaultPlan::random(43, 8, 1_000_000, 3);
        assert_ne!(a, c);
    }

    #[test]
    fn parse_round_trips_each_event_kind() {
        let p = FaultPlan::parse("crash:0:100;degrade:1:50:200:250;leave:2:300;join:3:10", 4)
            .unwrap();
        assert_eq!(p.events[0], vec![FaultEvent::Crash { at: ms_to_cycles(100.0) }]);
        assert_eq!(
            p.events[1],
            vec![FaultEvent::Degrade {
                at: ms_to_cycles(50.0),
                factor_pct: 200,
                until: ms_to_cycles(250.0),
            }]
        );
        assert_eq!(p.events[2], vec![FaultEvent::Leave { at: ms_to_cycles(300.0) }]);
        assert_eq!(p.events[3], vec![FaultEvent::Join { at: ms_to_cycles(10.0) }]);
        assert_eq!(p.initial_health(3), CardHealth::Down);
    }

    #[test]
    fn parse_rejects_nonsense() {
        assert!(FaultPlan::parse("crash:9:100", 4).is_err());
        assert!(FaultPlan::parse("degrade:0:10:50:20", 4).is_err()); // factor < 100
        assert!(FaultPlan::parse("explode:0:1", 4).is_err());
        assert!(FaultPlan::parse("none", 4).unwrap().is_empty());
        assert_eq!(FaultPlan::parse("rand:7:2", 4).unwrap().retry_budget, 2);
    }
}
