//! Serving front-end: request router + dynamic batcher over the PJRT
//! engines (the "host side" the paper leaves implicit).
//!
//! Threading model: PJRT handles are not assumed `Send`, so a single
//! **executor thread** owns the [`Runtime`] and all compiled engines;
//! clients talk to it through channels. The batcher accumulates requests
//! until `max_batch` or `max_wait`, then greedily decomposes the queue
//! into the available artifact batch sizes (8/4/2/1) — the same
//! largest-fit policy vLLM-style servers use for bucketed engines.
//! (tokio is not in the vendored registry; std threads are the
//! documented substitution, DESIGN.md §5.)

pub mod router;
pub mod workload;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::{Runtime, Tensor};
use crate::util::prng::Rng;

/// A classification request: one image, flattened (H·W·3) f32.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Batch size this request was served in (observability).
    pub batch: usize,
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Greedy largest-fit decomposition of `n` pending requests into the
/// available engine batch sizes (descending). Returns the batch sizes to
/// launch, covering all `n`.
pub fn decompose(n: usize, sizes_desc: &[usize]) -> Vec<usize> {
    let mut rem = n;
    let mut plan = Vec::new();
    for &s in sizes_desc {
        while rem >= s {
            plan.push(s);
            rem -= s;
        }
    }
    if rem > 0 {
        // smaller than the smallest engine: pad up to it
        plan.push(*sizes_desc.last().expect("no engine sizes"));
    }
    plan
}

/// Server statistics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub completed: u64,
    pub latencies_ms: Vec<f64>,
    pub batches: HashMap<usize, u64>,
    pub wall: Duration,
}

impl Metrics {
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        v[idx]
    }

    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

impl std::fmt::Display for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} requests in {:.2} s  ({:.1} req/s)",
            self.completed,
            self.wall.as_secs_f64(),
            self.throughput()
        )?;
        writeln!(
            f,
            "latency p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
            self.percentile_ms(0.50),
            self.percentile_ms(0.90),
            self.percentile_ms(0.99)
        )?;
        let mut sizes: Vec<_> = self.batches.iter().collect();
        sizes.sort();
        write!(f, "batch mix:")?;
        for (s, count) in sizes {
            write!(f, "  {s}×{count}")?;
        }
        Ok(())
    }
}

enum Cmd {
    Serve(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// Handle to the running server.
pub struct Server {
    tx: mpsc::Sender<Cmd>,
    worker: Option<thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Start the executor thread for the artifacts in `dir`. Blocks until
    /// every engine is compiled, so serving latencies never include
    /// compile time.
    pub fn start(dir: &Path, policy: BatchPolicy) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dir: PathBuf = dir.to_path_buf();
        let worker = thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_loop(&dir, policy, rx, ready_tx))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => anyhow::bail!("executor died during startup"),
        }
        Ok(Server {
            tx,
            worker: Some(worker),
        })
    }

    /// Submit a request; the response arrives on `resp`.
    pub fn submit(&self, req: Request, resp: mpsc::Sender<Response>) -> Result<()> {
        self.tx
            .send(Cmd::Serve(req, resp))
            .map_err(|_| anyhow::anyhow!("server thread gone"))
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.worker.take() {
            h.join().map_err(|_| anyhow::anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

fn executor_loop(
    dir: &Path,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Cmd>,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()> {
    let setup = (|| -> Result<(Vec<usize>, HashMap<usize, String>, Runtime)> {
        let rt = Runtime::new(dir)?;
        let serving = rt.serving_artifacts();
        anyhow::ensure!(!serving.is_empty(), "no serving artifacts in manifest");
        let mut sizes: Vec<usize> = serving.iter().map(|(b, _)| *b).collect();
        sizes.sort_by(|a, b| b.cmp(a)); // descending
        let by_size: HashMap<usize, String> =
            serving.into_iter().map(|(b, n)| (b, n)).collect();
        // compile everything up front (compile time must not pollute latency)
        for name in by_size.values() {
            rt.engine(name)?;
        }
        Ok((sizes, by_size, rt))
    })();
    let (sizes, by_size, rt) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready.send(Err(e));
            anyhow::bail!("executor startup failed: {msg}");
        }
    };
    // per-image element count, derived from one engine and its own batch
    let (&some_batch, some_name) = by_size.iter().next().unwrap();
    let img_len = rt.engine(some_name)?.info.inputs[0].numel() / some_batch;

    let mut pending: Vec<(Request, mpsc::Sender<Response>)> = Vec::new();
    let mut open = true;
    while open || !pending.is_empty() {
        // fill the batch window
        let deadline = Instant::now() + policy.max_wait;
        while open && pending.len() < policy.max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Cmd::Serve(r, c)) => pending.push((r, c)),
                Ok(Cmd::Shutdown) => open = false,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
            if pending.len() == 1 && policy.max_wait > Duration::ZERO {
                // window starts at first arrival
            }
        }
        if pending.is_empty() {
            continue;
        }
        // dispatch: greedy largest-fit over available engine sizes
        let plan = decompose(pending.len(), &sizes);
        for batch in plan {
            if pending.is_empty() {
                break;
            }
            let take = batch.min(pending.len());
            let group: Vec<_> = pending.drain(..take).collect();
            let name = &by_size[&batch];
            let eng = rt.engine(name)?;
            let mut input = Vec::with_capacity(batch * img_len);
            for (r, _) in &group {
                input.extend_from_slice(&r.image);
            }
            // pad with zero images when the group under-fills the engine
            input.resize(batch * img_len, 0.0);
            let out = eng.run(&[Tensor::F32(input)])?;
            let logits = out.as_f32()?;
            let classes = logits.len() / batch;
            let now = Instant::now();
            for (i, (r, c)) in group.into_iter().enumerate() {
                let _ = c.send(Response {
                    id: r.id,
                    logits: logits[i * classes..(i + 1) * classes].to_vec(),
                    latency: now.duration_since(r.enqueued),
                    batch,
                });
            }
        }
    }
    Ok(())
}

/// Closed-loop demo used by `swin-fpga serve` and the e2e bench: Poisson
/// arrivals at `rate` req/s, `total` requests, returns the metrics.
pub fn run_demo_metrics(
    dir: &Path,
    total: usize,
    rate: f64,
    max_batch: usize,
) -> Result<Metrics> {
    let server = Server::start(
        dir,
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(2),
        },
    )?;
    // image size from the manifest (all serving artifacts share it)
    let rt_manifest = crate::runtime::Manifest::load(dir)?;
    let (_, info) = rt_manifest
        .artifacts
        .iter()
        .find(|(_, a)| a.kind == "swin_float")
        .context("no serving artifact")?;
    let img_len = info.inputs[0].numel() / info.batch.unwrap_or(1);

    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    for id in 0..total {
        let image: Vec<f32> = (0..img_len).map(|_| rng.range_f32(0.0, 1.0)).collect();
        server.submit(
            Request {
                id: id as u64,
                image,
                enqueued: Instant::now(),
            },
            resp_tx.clone(),
        )?;
        let gap = rng.exp(1.0 / rate);
        thread::sleep(Duration::from_secs_f64(gap.min(0.1)));
    }
    drop(resp_tx);
    let mut metrics = Metrics::default();
    for resp in resp_rx.iter() {
        metrics.completed += 1;
        metrics.latencies_ms.push(resp.latency.as_secs_f64() * 1e3);
        *metrics.batches.entry(resp.batch).or_insert(0) += 1;
        if metrics.completed as usize == total {
            break;
        }
    }
    metrics.wall = t0.elapsed();
    server.shutdown()?;
    Ok(metrics)
}

/// String-summary wrapper for the CLI.
pub fn run_demo(dir: &Path, total: usize, rate: f64, max_batch: usize) -> Result<String> {
    Ok(run_demo_metrics(dir, total, rate, max_batch)?.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_greedy_largest_fit() {
        let sizes = [8usize, 4, 2, 1];
        assert_eq!(decompose(8, &sizes), vec![8]);
        assert_eq!(decompose(7, &sizes), vec![4, 2, 1]);
        assert_eq!(decompose(13, &sizes), vec![8, 4, 1]);
        assert_eq!(decompose(1, &sizes), vec![1]);
    }

    #[test]
    fn decompose_pads_below_minimum() {
        let sizes = [8usize, 4];
        // 3 requests with a min engine of 4: run one padded batch of 4
        assert_eq!(decompose(3, &sizes), vec![4]);
    }

    #[test]
    fn metrics_percentiles() {
        let m = Metrics {
            completed: 4,
            latencies_ms: vec![1.0, 2.0, 3.0, 100.0],
            batches: HashMap::new(),
            wall: Duration::from_secs(1),
        };
        assert!((m.percentile_ms(0.5) - 2.0).abs() < 1.01);
        assert!(m.percentile_ms(0.99) >= 3.0);
        assert!((m.throughput() - 4.0).abs() < 1e-9);
    }
}
