//! Serving front-end: a **continuous batcher** over one [`Engine`] per
//! executor thread, plus the fleet [`router`] that runs one batcher *per
//! card* and load-balances over `Vec<Box<dyn Engine>>` by modelled
//! backlog — the "host side" the paper leaves implicit.
//!
//! Threading model: PJRT handles are not assumed `Send`, so a single
//! executor thread *constructs and owns* its engine; clients talk to it
//! through a bounded channel (the backpressure point). The virtual-time
//! fleet experiments have a second, stricter threading story:
//! [`router::ShardedRouter`] partitions the cards into shards run on
//! `std::thread::scope` workers, requires `Box<dyn Engine + Send>` at
//! construction, and keeps every result a pure function of the arrival
//! stream — epoch-snapshot routing, counter-based per-shard PRNG
//! substreams ([`workload::ShardArrivalGen`]) and a deterministic k-way
//! drain merge make the output bit-identical for every thread count
//! (see the `router` module docs). Unlike the
//! original stop-the-world accumulate/flush cycle, the batcher admits new
//! requests while a launch is in flight and re-plans after **every**
//! launch. The batch-formation core lives in [`batcher::CardBatcher`]
//! (shared with the virtual-time fleet router): it greedily picks the
//! largest artifact bucket (8/4/2/1) the current queue fills, pads only
//! when the queue is below the smallest bucket, and flushes when the
//! earliest queued **class deadline** expires — each request carries an
//! [`Slo`] class ([`Slo::Interactive`] / [`Slo::Batch`]) with its own
//! `max_wait` ([`SloPolicy`]), so one overdue interactive request flushes
//! a bucket early while batch traffic keeps accumulating occupancy.
//! Seats are filled overdue-first (no starvation), then class-homogeneous
//! (see `rust/tests/serving_batcher.rs`).
//!
//! Backpressure: the admission queue is bounded (`queue_cap`); on
//! overflow the submitter either blocks ([`Overload::Block`]) or the
//! request is shed ([`Overload::Shed`]).
//!
//! (tokio is not in the vendored registry; std threads are the
//! documented substitution, DESIGN.md §5.)

pub mod batcher;
pub mod engine;
pub mod fault;
pub mod router;
pub mod scrape;
pub mod sharded;
pub mod workload;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;
use crate::util::prng::Rng;
use crate::util::stats::Reservoir;

pub use batcher::{decompose, pick_launch, BatchItem, CardBatcher, Slo, SloPolicy, Step};
pub use engine::{BatchOutput, Engine, PjrtEngine, ServicePrior, SimEngine, BUCKET_SIZES};
pub use fault::{CardHealth, FaultEvent, FaultPlan};
pub use scrape::{MetricsHub, ScrapeServer};
pub use sharded::ShardedEngine;

/// A classification request: one image, flattened (H·W·3) f32.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
    /// Service class (per-class flush deadline; see [`SloPolicy`]).
    pub class: Slo,
}

impl Request {
    /// An interactive-class request enqueued now (the common case).
    pub fn new(id: u64, image: Vec<f32>) -> Request {
        Request {
            id,
            image,
            enqueued: Instant::now(),
            class: Slo::Interactive,
        }
    }

    pub fn with_class(mut self, class: Slo) -> Request {
        self.class = class;
        self
    }
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Launch (bucket) size this request was served in.
    pub batch: usize,
    /// Requests actually filling that launch (rest was zero-padding).
    pub occupancy: usize,
    /// Executor queue depth at dispatch (observability).
    pub queue_depth: usize,
    /// Service class the request was admitted with.
    pub class: Slo,
    /// Card (engine id) that served the launch.
    pub card: usize,
}

/// What to do when the admission queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Overload {
    /// Block the submitter until space frees (closed-loop clients).
    Block,
    /// Reject immediately; [`Server::submit`] returns `Ok(false)`.
    Shed,
}

/// Batch-formation strategy (the continuous/stop-the-world ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Admit while in flight; re-plan after every launch; flush on the
    /// earliest queued class deadline.
    Continuous,
    /// The seed's accumulate/flush cycle: fill a window (deadline armed
    /// at window start), then execute the whole greedy plan without
    /// admitting. Kept for the ablation bench.
    StopTheWorld,
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// Flush deadline when no per-class policy is set (both classes).
    pub max_wait: Duration,
    /// Admission-queue bound (requests), the backpressure point.
    pub queue_cap: usize,
    pub overload: Overload,
    pub mode: BatchMode,
    /// Per-class flush deadlines; `None` applies `max_wait` to both
    /// classes (the pre-SLO behaviour).
    pub slo: Option<SloPolicy>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
            overload: Overload::Block,
            mode: BatchMode::Continuous,
            slo: None,
        }
    }
}

impl BatchPolicy {
    /// Per-class max waits `[interactive, batch]`.
    pub fn class_waits(&self) -> [Duration; 2] {
        match self.slo {
            Some(s) => [s.interactive_max_wait, s.batch_max_wait],
            None => [self.max_wait, self.max_wait],
        }
    }
}

/// Server statistics. Percentile series are fixed-size reservoirs
/// ([`Reservoir`]): a long-running serve process holds O(cap) memory no
/// matter how many requests it completes.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub completed: u64,
    /// Requests rejected by [`Overload::Shed`].
    pub shed: u64,
    pub latencies_ms: Reservoir,
    /// Per-class latency reservoirs, indexed by [`Slo::idx`].
    pub class_latencies_ms: [Reservoir; 2],
    /// Completed requests per class, indexed by [`Slo::idx`].
    pub class_completed: [u64; 2],
    /// Launch-size histogram (one count per served request,
    /// seed-compatible; bounded by the bucket count).
    pub batches: HashMap<usize, u64>,
    /// Per-request occupancy fraction (filled seats ÷ launch size).
    pub occupancy_fracs: Reservoir,
    /// Executor queue depth sampled at each dispatch.
    pub queue_depths: Reservoir,
    /// Exact stream maximum of the dispatch queue depth.
    pub queue_depth_peak: usize,
    pub wall: Duration,
    /// Fault-layer counters (virtual-time fleet runs only; the
    /// wall-clock executor has no fault injection, so they stay zero
    /// there). Re-launch attempts after crash loss.
    pub retries: u64,
    /// Requests redistributed through the normal assignment path (crash
    /// survivors plus a leaving card's drained queue).
    pub redispatches: u64,
    /// In-flight results retracted by fail-stop crashes.
    pub crash_losses: u64,
    /// Requests lost for good (retry budget exhausted, or no live card
    /// to redispatch to).
    pub lost: u64,
    /// Cards per health state at end of run, indexed
    /// `[up, degraded, draining, down]`.
    pub cards_by_health: [u64; 4],
}

impl Metrics {
    pub fn record(&mut self, resp: &Response) {
        self.completed += 1;
        let lat_ms = resp.latency.as_secs_f64() * 1e3;
        self.latencies_ms.push(lat_ms);
        self.class_latencies_ms[resp.class.idx()].push(lat_ms);
        self.class_completed[resp.class.idx()] += 1;
        *self.batches.entry(resp.batch).or_insert(0) += 1;
        self.occupancy_fracs
            .push(resp.occupancy as f64 / resp.batch.max(1) as f64);
        self.queue_depths.push(resp.queue_depth as f64);
        self.queue_depth_peak = self.queue_depth_peak.max(resp.queue_depth);
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.latencies_ms.percentile(p)
    }

    /// Latency percentile of one service class.
    pub fn class_percentile_ms(&self, class: Slo, p: f64) -> f64 {
        self.class_latencies_ms[class.idx()].percentile(p)
    }

    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Mean batch occupancy (1.0 = every launch completely full).
    pub fn occupancy_mean(&self) -> f64 {
        self.occupancy_fracs.mean()
    }

    pub fn queue_depth_max(&self) -> usize {
        self.queue_depth_peak
    }
}

impl std::fmt::Display for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} requests in {:.2} s  ({:.1} req/s, {} shed)",
            self.completed,
            self.wall.as_secs_f64(),
            self.throughput(),
            self.shed
        )?;
        writeln!(
            f,
            "latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
            self.percentile_ms(0.50),
            self.percentile_ms(0.95),
            self.percentile_ms(0.99)
        )?;
        if Slo::ALL.iter().all(|&c| self.class_completed[c.idx()] > 0) {
            writeln!(
                f,
                "class p99: interactive {:.2} ms ({})  batch {:.2} ms ({})",
                self.class_percentile_ms(Slo::Interactive, 0.99),
                self.class_completed[Slo::Interactive.idx()],
                self.class_percentile_ms(Slo::Batch, 0.99),
                self.class_completed[Slo::Batch.idx()],
            )?;
        }
        writeln!(
            f,
            "occupancy {:.0}%  max queue depth {}",
            self.occupancy_mean() * 100.0,
            self.queue_depth_max()
        )?;
        if self.retries + self.redispatches + self.crash_losses + self.lost > 0
            || self.cards_by_health != [0; 4]
        {
            writeln!(
                f,
                "faults: {} retries  {} redispatched  {} crash-lost  {} lost  \
                 cards up/deg/drain/down {}/{}/{}/{}",
                self.retries,
                self.redispatches,
                self.crash_losses,
                self.lost,
                self.cards_by_health[0],
                self.cards_by_health[1],
                self.cards_by_health[2],
                self.cards_by_health[3],
            )?;
        }
        let mut sizes: Vec<_> = self.batches.iter().collect();
        sizes.sort();
        write!(f, "batch mix:")?;
        for (s, count) in sizes {
            write!(f, "  {s}×{count}")?;
        }
        Ok(())
    }
}

enum Cmd {
    Serve(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// Handle to the running server.
pub struct Server {
    tx: mpsc::SyncSender<Cmd>,
    worker: Option<thread::JoinHandle<Result<()>>>,
    overload: Overload,
    shed: AtomicU64,
}

impl Server {
    /// Start an executor thread over the PJRT artifacts in `dir`. Blocks
    /// until every bucket engine is compiled, so serving latencies never
    /// include compile time.
    pub fn start(dir: &Path, policy: BatchPolicy) -> Result<Server> {
        let dir: PathBuf = dir.to_path_buf();
        Server::start_with(policy, move || {
            Ok(Box::new(PjrtEngine::new(&dir)?) as Box<dyn Engine>)
        })
    }

    /// Start an executor thread over a simulated card (no artifacts or
    /// PJRT needed). `time_scale` scales how much of the modelled service
    /// time is actually slept per launch (0 = none).
    pub fn start_sim(
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        time_scale: f64,
        policy: BatchPolicy,
    ) -> Result<Server> {
        Server::start_with(policy, move || {
            Ok(Box::new(SimEngine::new(0, variant, cfg, time_scale)) as Box<dyn Engine>)
        })
    }

    /// Start an executor thread over any engine. The factory runs *inside*
    /// the executor thread (PJRT handles need not be `Send`).
    pub fn start_with<F>(policy: BatchPolicy, factory: F) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Cmd>(policy.queue_cap.max(1));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let overload = policy.overload;
        let worker = thread::Builder::new()
            .name("serve-executor".into())
            .spawn(move || executor_entry(factory, policy, rx, ready_tx))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => anyhow::bail!("executor died during startup"),
        }
        Ok(Server {
            tx,
            worker: Some(worker),
            overload,
            shed: AtomicU64::new(0),
        })
    }

    /// Submit a request; the response arrives on `resp`. Returns
    /// `Ok(true)` when admitted, `Ok(false)` when shed by backpressure
    /// ([`Overload::Shed`] with a full queue), `Err` when the server is
    /// gone. With [`Overload::Block`] a full queue blocks the caller.
    pub fn submit(&self, req: Request, resp: mpsc::Sender<Response>) -> Result<bool> {
        match self.overload {
            Overload::Block => self
                .tx
                .send(Cmd::Serve(req, resp))
                .map(|()| true)
                .map_err(|_| anyhow::anyhow!("server thread gone")),
            Overload::Shed => match self.tx.try_send(Cmd::Serve(req, resp)) {
                Ok(()) => Ok(true),
                Err(mpsc::TrySendError::Full(_)) => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    Ok(false)
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    Err(anyhow::anyhow!("server thread gone"))
                }
            },
        }
    }

    /// Requests shed so far (only grows under [`Overload::Shed`]).
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.worker.take() {
            h.join().map_err(|_| anyhow::anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

fn executor_entry<F>(
    factory: F,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Cmd>,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()>
where
    F: FnOnce() -> Result<Box<dyn Engine>>,
{
    let engine = match factory() {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready.send(Err(e));
            anyhow::bail!("executor startup failed: {msg}");
        }
    };
    match policy.mode {
        BatchMode::Continuous => continuous_loop(engine, &policy, rx),
        BatchMode::StopTheWorld => stop_the_world_loop(engine, &policy, rx),
    }
}

type Client = mpsc::Sender<Response>;

/// Execute one launch over an already-selected group and answer every
/// filled seat (shared by both batching loops).
fn run_and_respond(
    engine: &mut dyn Engine,
    group: Vec<(Request, Client)>,
    launch: usize,
    depth: usize,
) -> Result<()> {
    let img_len = engine.image_len();
    let classes = engine.num_classes();
    let card = engine.card_id();
    let take = group.len();
    let mut input = Vec::with_capacity(launch * img_len);
    for (r, _) in &group {
        input.extend_from_slice(&r.image);
    }
    // pad with zero images when the group under-fills the bucket
    input.resize(launch * img_len, 0.0);
    let out = engine.run_batch(launch, &input)?;
    let now = Instant::now();
    for (i, (r, c)) in group.into_iter().enumerate() {
        let _ = c.send(Response {
            id: r.id,
            logits: out.logits[i * classes..(i + 1) * classes].to_vec(),
            latency: now.duration_since(r.enqueued),
            batch: launch,
            occupancy: take,
            queue_depth: depth,
            class: r.class,
            card,
        });
    }
    Ok(())
}

/// Run one launch off a [`CardBatcher`] queue: deadline/class-aware seat
/// selection at tick `now`, then execute and respond.
fn launch_from_batcher(
    engine: &mut dyn Engine,
    queue: &mut CardBatcher<(Request, Client)>,
    launch: usize,
    now: u64,
) -> Result<()> {
    let depth = queue.len();
    let group: Vec<(Request, Client)> = queue
        .take_launch(launch, now)
        .into_iter()
        .map(|it| it.payload)
        .collect();
    run_and_respond(engine, group, launch, depth)
}

fn continuous_loop(
    mut engine: Box<dyn Engine>,
    policy: &BatchPolicy,
    rx: mpsc::Receiver<Cmd>,
) -> Result<()> {
    let sizes = engine.batch_sizes().to_vec();
    // CardBatcher ticks are nanoseconds since the executor started;
    // deadlines stay anchored to each request's submit instant.
    let anchor = Instant::now();
    let ticks = |t: Instant| t.saturating_duration_since(anchor).as_nanos() as u64;
    let waits = policy.class_waits();
    let mut queue: CardBatcher<(Request, Client)> = CardBatcher::new(
        sizes,
        policy.max_batch,
        policy.queue_cap.max(1),
        [waits[0].as_nanos() as u64, waits[1].as_nanos() as u64],
    );
    let mut open = true;
    while open || !queue.is_empty() {
        // continuous admission: drain whatever arrived while the last
        // launch was in flight. The executor-side queue is bounded too, so
        // total in-flight work stays under ~2 × queue_cap (channel +
        // queue); the channel is the actual backpressure point.
        while queue.len() < queue.cap() {
            match rx.try_recv() {
                Ok(Cmd::Serve(r, c)) => {
                    let (class, at) = (r.class, ticks(r.enqueued));
                    queue.push((r, c), class, at);
                }
                Ok(Cmd::Shutdown) => open = false,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if queue.is_empty() {
            if !open {
                break;
            }
            // idle: park until the next command
            match rx.recv() {
                Ok(Cmd::Serve(r, c)) => {
                    let (class, at) = (r.class, ticks(r.enqueued));
                    queue.push((r, c), class, at);
                }
                Ok(Cmd::Shutdown) | Err(_) => open = false,
            }
            continue;
        }
        if !open {
            // shutdown: drain the remaining queue without waiting
            let launch = queue.flush_launch();
            let now = ticks(Instant::now());
            launch_from_batcher(engine.as_mut(), &mut queue, launch, now)?;
            continue;
        }
        // a full bucket (or a queue at cap) launches immediately;
        // otherwise wait for arrivals, but never past the earliest queued
        // class deadline (armed from each request's `enqueued`)
        let now = ticks(Instant::now());
        match queue.step(now) {
            Step::Launch(launch) => {
                launch_from_batcher(engine.as_mut(), &mut queue, launch, now)?;
            }
            Step::Wait(due) => {
                let deadline = anchor + Duration::from_nanos(due);
                match rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
                    Ok(Cmd::Serve(r, c)) => {
                        // re-plan with the newcomer admitted
                        let (class, at) = (r.class, ticks(r.enqueued));
                        queue.push((r, c), class, at);
                    }
                    Ok(Cmd::Shutdown) => open = false,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        // deadline: flush
                        let launch = queue.flush_launch();
                        let now = ticks(Instant::now());
                        launch_from_batcher(engine.as_mut(), &mut queue, launch, now)?;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
            Step::Idle => unreachable!("non-empty queue"),
        }
    }
    Ok(())
}

/// The seed's accumulate/flush cycle, kept verbatim-in-spirit for the
/// ablation bench: window deadline armed at window start, whole plan
/// executed with no admission in between, FIFO seats (no SLO classes).
fn stop_the_world_loop(
    mut engine: Box<dyn Engine>,
    policy: &BatchPolicy,
    rx: mpsc::Receiver<Cmd>,
) -> Result<()> {
    let sizes = engine.batch_sizes().to_vec();
    let mut queue: std::collections::VecDeque<(Request, Client)> =
        std::collections::VecDeque::new();
    let mut open = true;
    while open || !queue.is_empty() {
        let deadline = Instant::now() + policy.max_wait;
        while open && queue.len() < policy.max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Cmd::Serve(r, c)) => queue.push_back((r, c)),
                Ok(Cmd::Shutdown) => open = false,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if queue.is_empty() {
            continue;
        }
        let plan = decompose(queue.len(), &sizes);
        for launch in plan {
            if queue.is_empty() {
                break;
            }
            let depth = queue.len();
            let take = launch.min(depth);
            let group: Vec<_> = queue.drain(..take).collect();
            run_and_respond(engine.as_mut(), group, launch, depth)?;
        }
    }
    Ok(())
}

/// Closed-loop demo against the PJRT backend: Poisson arrivals at `rate`
/// req/s, `total` requests, returns the metrics. Requires artifacts.
pub fn run_demo_metrics(
    dir: &Path,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
) -> Result<Metrics> {
    run_demo_metrics_observed(dir, total, rate, policy, 1.0, None)
}

/// [`run_demo_metrics`] with a class mix (`interactive_share` of traffic
/// tagged [`Slo::Interactive`]) and a live [`MetricsHub`] for the scrape
/// endpoint (updated per response — including sheds — not just at the
/// end of the run).
pub fn run_demo_metrics_observed(
    dir: &Path,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
    interactive_share: f64,
    hub: Option<Arc<MetricsHub>>,
) -> Result<Metrics> {
    // image size from the manifest (all serving artifacts share it)
    let manifest = crate::runtime::Manifest::load(dir)?;
    let (_, info) = manifest
        .artifacts
        .iter()
        .find(|(_, a)| a.kind == "swin_float")
        .context("no serving artifact")?;
    let img_len = info.inputs[0].numel() / info.batch.unwrap_or(1);
    let server = Server::start(dir, policy)?;
    drive(server, img_len, total, rate, interactive_share, hub)
}

/// Closed-loop demo against a simulated card: no artifacts needed.
pub fn run_demo_metrics_sim(
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    time_scale: f64,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
) -> Result<Metrics> {
    run_demo_metrics_sim_observed(variant, cfg, time_scale, total, rate, policy, 1.0, None)
}

/// [`run_demo_metrics_sim`] with a class mix and a live [`MetricsHub`]
/// for the scrape endpoint.
#[allow(clippy::too_many_arguments)]
pub fn run_demo_metrics_sim_observed(
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    time_scale: f64,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
    interactive_share: f64,
    hub: Option<Arc<MetricsHub>>,
) -> Result<Metrics> {
    let img_len = variant.img_size * variant.img_size * variant.in_chans;
    let server = Server::start_sim(variant, cfg, time_scale, policy)?;
    drive(server, img_len, total, rate, interactive_share, hub)
}

/// Drive a server with Poisson arrivals and collect the metrics.
fn drive(
    server: Server,
    img_len: usize,
    total: usize,
    rate: f64,
    interactive_share: f64,
    hub: Option<Arc<MetricsHub>>,
) -> Result<Metrics> {
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let mut rng = Rng::new(7);
    let mut metrics = Metrics::default();
    let mut admitted = 0usize;
    let t0 = Instant::now();
    for id in 0..total {
        let image: Vec<f32> = (0..img_len).map(|_| rng.range_f32(0.0, 1.0)).collect();
        let class = if rng.f64() < interactive_share {
            Slo::Interactive
        } else {
            Slo::Batch
        };
        if server.submit(
            Request {
                id: id as u64,
                image,
                enqueued: Instant::now(),
                class,
            },
            resp_tx.clone(),
        )? {
            admitted += 1;
        } else if let Some(h) = &hub {
            // live shed count: scrapes see drops as they happen
            h.record_shed();
        }
        let gap = rng.exp(1.0 / rate);
        thread::sleep(Duration::from_secs_f64(gap.min(0.1)));
    }
    drop(resp_tx);
    for resp in resp_rx.iter() {
        metrics.record(&resp);
        if let Some(h) = &hub {
            h.record(&resp);
        }
        if metrics.completed as usize == admitted {
            break;
        }
    }
    metrics.wall = t0.elapsed();
    metrics.shed = server.shed_count();
    if let Some(h) = &hub {
        h.finish(metrics.shed, metrics.wall);
    }
    server.shutdown()?;
    Ok(metrics)
}

/// String-summary wrapper for the CLI.
pub fn run_demo(dir: &Path, total: usize, rate: f64, max_batch: usize) -> Result<String> {
    let policy = BatchPolicy {
        max_batch,
        ..Default::default()
    };
    Ok(run_demo_metrics(dir, total, rate, policy)?.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, batch: usize, occupancy: usize, depth: usize, ms: u64) -> Response {
        Response {
            id,
            logits: vec![],
            latency: Duration::from_millis(ms),
            batch,
            occupancy,
            queue_depth: depth,
            class: Slo::Interactive,
            card: 0,
        }
    }

    #[test]
    fn metrics_percentiles() {
        let mut m = Metrics::default();
        for ms in [1, 2, 3, 100] {
            m.record(&resp(0, 1, 1, 1, ms));
        }
        m.wall = Duration::from_secs(1);
        assert!((m.percentile_ms(0.5) - 2.0).abs() < 1.01);
        assert!(m.percentile_ms(0.99) >= 3.0);
        assert!((m.throughput() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_occupancy_and_depth() {
        let mut m = Metrics::default();
        m.record(&resp(0, 8, 6, 11, 1));
        m.record(&resp(1, 4, 4, 3, 2));
        assert!((m.occupancy_mean() - (0.75 + 1.0) / 2.0).abs() < 1e-12);
        assert_eq!(m.queue_depth_max(), 11);
        assert_eq!(m.batches[&8], 1);
        assert_eq!(m.batches[&4], 1);
    }

    #[test]
    fn metrics_memory_stays_bounded() {
        // the long-running-serve leak regression: millions of responses,
        // O(reservoir) memory, percentile API still answers
        let mut m = Metrics::default();
        for i in 0..50_000u64 {
            m.record(&resp(i, 8, 8, (i % 31) as usize, i % 97));
        }
        assert_eq!(m.completed, 50_000);
        assert_eq!(m.latencies_ms.seen(), 50_000);
        assert!(m.latencies_ms.len() <= m.latencies_ms.cap());
        assert!(m.occupancy_fracs.len() <= m.occupancy_fracs.cap());
        assert!(m.queue_depths.len() <= m.queue_depths.cap());
        assert_eq!(m.queue_depth_max(), 30); // exact despite sampling
        let p50 = m.percentile_ms(0.5);
        assert!(p50 > 30.0 && p50 < 70.0, "p50={p50}");
    }

    #[test]
    fn metrics_split_by_class() {
        let mut m = Metrics::default();
        m.record(&resp(0, 1, 1, 1, 2));
        let mut b = resp(1, 8, 8, 9, 40);
        b.class = Slo::Batch;
        m.record(&b);
        assert_eq!(m.class_completed, [1, 1]);
        assert!(m.class_percentile_ms(Slo::Interactive, 0.99) < 10.0);
        assert!(m.class_percentile_ms(Slo::Batch, 0.99) > 30.0);
        let s = m.to_string();
        assert!(s.contains("class p99"), "{s}");
    }

    #[test]
    fn batch_policy_class_waits() {
        let p = BatchPolicy::default();
        assert_eq!(p.class_waits(), [p.max_wait, p.max_wait]);
        let p = BatchPolicy {
            slo: Some(SloPolicy {
                interactive_max_wait: Duration::from_millis(1),
                batch_max_wait: Duration::from_millis(30),
            }),
            ..Default::default()
        };
        assert_eq!(
            p.class_waits(),
            [Duration::from_millis(1), Duration::from_millis(30)]
        );
    }
}
