//! Serving front-end: a **continuous batcher** over one [`Engine`] per
//! executor thread, plus the fleet [`router`] that load-balances over
//! `Vec<Box<dyn Engine>>` — the "host side" the paper leaves implicit.
//!
//! Threading model: PJRT handles are not assumed `Send`, so a single
//! executor thread *constructs and owns* its engine; clients talk to it
//! through a bounded channel (the backpressure point). Unlike the
//! original stop-the-world accumulate/flush cycle, the batcher admits new
//! requests while a launch is in flight and re-plans after **every**
//! launch: it greedily picks the largest artifact bucket (8/4/2/1) the
//! current queue fills, pads only when the queue is below the smallest
//! bucket, and flushes when either a full bucket is available or the
//! *oldest* queued request has waited `max_wait` (deadline armed from its
//! `enqueued` instant — not from the window start, which could starve a
//! flush past the SLO; see `rust/tests/serving_batcher.rs`).
//!
//! Backpressure: the admission queue is bounded (`queue_cap`); on
//! overflow the submitter either blocks ([`Overload::Block`]) or the
//! request is shed ([`Overload::Shed`]).
//!
//! (tokio is not in the vendored registry; std threads are the
//! documented substitution, DESIGN.md §5.)

pub mod engine;
pub mod router;
pub mod scrape;
pub mod workload;

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::accel::AccelConfig;
use crate::model::config::SwinVariant;
use crate::util::prng::Rng;

pub use engine::{BatchOutput, Engine, PjrtEngine, ServicePrior, SimEngine, BUCKET_SIZES};
pub use scrape::{MetricsHub, ScrapeServer};

/// A classification request: one image, flattened (H·W·3) f32.
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub enqueued: Instant,
}

/// The server's answer.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Launch (bucket) size this request was served in.
    pub batch: usize,
    /// Requests actually filling that launch (rest was zero-padding).
    pub occupancy: usize,
    /// Executor queue depth at dispatch (observability).
    pub queue_depth: usize,
}

/// What to do when the admission queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Overload {
    /// Block the submitter until space frees (closed-loop clients).
    Block,
    /// Reject immediately; [`Server::submit`] returns `Ok(false)`.
    Shed,
}

/// Batch-formation strategy (the continuous/stop-the-world ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Admit while in flight; re-plan after every launch; flush on the
    /// oldest request's deadline.
    Continuous,
    /// The seed's accumulate/flush cycle: fill a window (deadline armed
    /// at window start), then execute the whole greedy plan without
    /// admitting. Kept for the ablation bench.
    StopTheWorld,
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission-queue bound (requests), the backpressure point.
    pub queue_cap: usize,
    pub overload: Overload,
    pub mode: BatchMode,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 256,
            overload: Overload::Block,
            mode: BatchMode::Continuous,
        }
    }
}

/// Greedy largest-fit decomposition of `n` pending requests into the
/// available engine batch sizes (descending). Returns the batch sizes to
/// launch, covering all `n`.
pub fn decompose(n: usize, sizes_desc: &[usize]) -> Vec<usize> {
    let mut rem = n;
    let mut plan = Vec::new();
    for &s in sizes_desc {
        while rem >= s {
            plan.push(s);
            rem -= s;
        }
    }
    if rem > 0 {
        // smaller than the smallest engine: pad up to it
        plan.push(*sizes_desc.last().expect("no engine sizes"));
    }
    plan
}

/// The single next launch for a queue of `n` requests: the largest bucket
/// the queue fills, or the smallest bucket (padded) when it fills none.
pub fn pick_launch(n: usize, sizes_desc: &[usize]) -> usize {
    sizes_desc
        .iter()
        .copied()
        .find(|&s| s <= n)
        .unwrap_or_else(|| *sizes_desc.last().expect("no engine sizes"))
}

/// Server statistics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub completed: u64,
    /// Requests rejected by [`Overload::Shed`].
    pub shed: u64,
    pub latencies_ms: Vec<f64>,
    /// Launch-size histogram (one count per served request, seed-compatible).
    pub batches: HashMap<usize, u64>,
    /// Per-request occupancy fraction (filled seats ÷ launch size).
    pub occupancy_fracs: Vec<f64>,
    /// Executor queue depth sampled at each dispatch.
    pub queue_depths: Vec<usize>,
    pub wall: Duration,
}

impl Metrics {
    pub fn record(&mut self, resp: &Response) {
        self.completed += 1;
        self.latencies_ms.push(resp.latency.as_secs_f64() * 1e3);
        *self.batches.entry(resp.batch).or_insert(0) += 1;
        self.occupancy_fracs
            .push(resp.occupancy as f64 / resp.batch.max(1) as f64);
        self.queue_depths.push(resp.queue_depth);
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((v.len() as f64 - 1.0) * p).round() as usize;
        v[idx]
    }

    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Mean batch occupancy (1.0 = every launch completely full).
    pub fn occupancy_mean(&self) -> f64 {
        if self.occupancy_fracs.is_empty() {
            return 0.0;
        }
        self.occupancy_fracs.iter().sum::<f64>() / self.occupancy_fracs.len() as f64
    }

    pub fn queue_depth_max(&self) -> usize {
        self.queue_depths.iter().copied().max().unwrap_or(0)
    }
}

impl std::fmt::Display for Metrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "served {} requests in {:.2} s  ({:.1} req/s, {} shed)",
            self.completed,
            self.wall.as_secs_f64(),
            self.throughput(),
            self.shed
        )?;
        writeln!(
            f,
            "latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
            self.percentile_ms(0.50),
            self.percentile_ms(0.95),
            self.percentile_ms(0.99)
        )?;
        writeln!(
            f,
            "occupancy {:.0}%  max queue depth {}",
            self.occupancy_mean() * 100.0,
            self.queue_depth_max()
        )?;
        let mut sizes: Vec<_> = self.batches.iter().collect();
        sizes.sort();
        write!(f, "batch mix:")?;
        for (s, count) in sizes {
            write!(f, "  {s}×{count}")?;
        }
        Ok(())
    }
}

enum Cmd {
    Serve(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// Handle to the running server.
pub struct Server {
    tx: mpsc::SyncSender<Cmd>,
    worker: Option<thread::JoinHandle<Result<()>>>,
    overload: Overload,
    shed: AtomicU64,
}

impl Server {
    /// Start an executor thread over the PJRT artifacts in `dir`. Blocks
    /// until every bucket engine is compiled, so serving latencies never
    /// include compile time.
    pub fn start(dir: &Path, policy: BatchPolicy) -> Result<Server> {
        let dir: PathBuf = dir.to_path_buf();
        Server::start_with(policy, move || {
            Ok(Box::new(PjrtEngine::new(&dir)?) as Box<dyn Engine>)
        })
    }

    /// Start an executor thread over a simulated card (no artifacts or
    /// PJRT needed). `time_scale` scales how much of the modelled service
    /// time is actually slept per launch (0 = none).
    pub fn start_sim(
        variant: &'static SwinVariant,
        cfg: AccelConfig,
        time_scale: f64,
        policy: BatchPolicy,
    ) -> Result<Server> {
        Server::start_with(policy, move || {
            Ok(Box::new(SimEngine::new(0, variant, cfg, time_scale)) as Box<dyn Engine>)
        })
    }

    /// Start an executor thread over any engine. The factory runs *inside*
    /// the executor thread (PJRT handles need not be `Send`).
    pub fn start_with<F>(policy: BatchPolicy, factory: F) -> Result<Server>
    where
        F: FnOnce() -> Result<Box<dyn Engine>> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Cmd>(policy.queue_cap.max(1));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let overload = policy.overload;
        let worker = thread::Builder::new()
            .name("serve-executor".into())
            .spawn(move || executor_entry(factory, policy, rx, ready_tx))?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => anyhow::bail!("executor died during startup"),
        }
        Ok(Server {
            tx,
            worker: Some(worker),
            overload,
            shed: AtomicU64::new(0),
        })
    }

    /// Submit a request; the response arrives on `resp`. Returns
    /// `Ok(true)` when admitted, `Ok(false)` when shed by backpressure
    /// ([`Overload::Shed`] with a full queue), `Err` when the server is
    /// gone. With [`Overload::Block`] a full queue blocks the caller.
    pub fn submit(&self, req: Request, resp: mpsc::Sender<Response>) -> Result<bool> {
        match self.overload {
            Overload::Block => self
                .tx
                .send(Cmd::Serve(req, resp))
                .map(|()| true)
                .map_err(|_| anyhow::anyhow!("server thread gone")),
            Overload::Shed => match self.tx.try_send(Cmd::Serve(req, resp)) {
                Ok(()) => Ok(true),
                Err(mpsc::TrySendError::Full(_)) => {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    Ok(false)
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    Err(anyhow::anyhow!("server thread gone"))
                }
            },
        }
    }

    /// Requests shed so far (only grows under [`Overload::Shed`]).
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.worker.take() {
            h.join().map_err(|_| anyhow::anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

fn executor_entry<F>(
    factory: F,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Cmd>,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()>
where
    F: FnOnce() -> Result<Box<dyn Engine>>,
{
    let engine = match factory() {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready.send(Err(e));
            anyhow::bail!("executor startup failed: {msg}");
        }
    };
    match policy.mode {
        BatchMode::Continuous => continuous_loop(engine, &policy, rx),
        BatchMode::StopTheWorld => stop_the_world_loop(engine, &policy, rx),
    }
}

type Pending = VecDeque<(Request, mpsc::Sender<Response>)>;

/// Run one launch: take up to `launch` requests off the queue, pad the
/// input to the bucket and answer every filled seat.
fn launch_group(engine: &mut dyn Engine, queue: &mut Pending, launch: usize) -> Result<()> {
    let img_len = engine.image_len();
    let classes = engine.num_classes();
    let depth = queue.len();
    let take = launch.min(depth);
    let group: Vec<_> = queue.drain(..take).collect();
    let mut input = Vec::with_capacity(launch * img_len);
    for (r, _) in &group {
        input.extend_from_slice(&r.image);
    }
    // pad with zero images when the group under-fills the bucket
    input.resize(launch * img_len, 0.0);
    let out = engine.run_batch(launch, &input)?;
    let now = Instant::now();
    for (i, (r, c)) in group.into_iter().enumerate() {
        let _ = c.send(Response {
            id: r.id,
            logits: out.logits[i * classes..(i + 1) * classes].to_vec(),
            latency: now.duration_since(r.enqueued),
            batch: launch,
            occupancy: take,
            queue_depth: depth,
        });
    }
    Ok(())
}

fn continuous_loop(
    mut engine: Box<dyn Engine>,
    policy: &BatchPolicy,
    rx: mpsc::Receiver<Cmd>,
) -> Result<()> {
    let sizes = engine.batch_sizes().to_vec();
    let mut queue: Pending = VecDeque::new();
    let mut open = true;
    while open || !queue.is_empty() {
        // continuous admission: drain whatever arrived while the last
        // launch was in flight. The executor-side queue is bounded too, so
        // total in-flight work stays under ~2 × queue_cap (channel +
        // queue); the channel is the actual backpressure point.
        while queue.len() < policy.queue_cap.max(1) {
            match rx.try_recv() {
                Ok(Cmd::Serve(r, c)) => queue.push_back((r, c)),
                Ok(Cmd::Shutdown) => open = false,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if queue.is_empty() {
            if !open {
                break;
            }
            // idle: park until the next command
            match rx.recv() {
                Ok(Cmd::Serve(r, c)) => queue.push_back((r, c)),
                Ok(Cmd::Shutdown) | Err(_) => open = false,
            }
            continue;
        }
        // a full bucket always launches; otherwise wait for arrivals, but
        // never past the oldest request's deadline (armed from `enqueued`)
        let full = pick_launch(policy.max_batch, &sizes);
        if open && queue.len() < full && queue.len() < policy.queue_cap {
            let deadline = queue.front().expect("non-empty").0.enqueued + policy.max_wait;
            let now = Instant::now();
            if now < deadline {
                match rx.recv_timeout(deadline.saturating_duration_since(now)) {
                    Ok(Cmd::Serve(r, c)) => {
                        queue.push_back((r, c));
                        continue; // re-plan with the newcomer admitted
                    }
                    Ok(Cmd::Shutdown) => {
                        open = false;
                        continue; // drain remaining queue without waiting
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {} // deadline: flush
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
        }
        let launch = pick_launch(queue.len().min(policy.max_batch), &sizes);
        launch_group(engine.as_mut(), &mut queue, launch)?;
    }
    Ok(())
}

/// The seed's accumulate/flush cycle, kept verbatim-in-spirit for the
/// ablation bench: window deadline armed at window start, whole plan
/// executed with no admission in between.
fn stop_the_world_loop(
    mut engine: Box<dyn Engine>,
    policy: &BatchPolicy,
    rx: mpsc::Receiver<Cmd>,
) -> Result<()> {
    let sizes = engine.batch_sizes().to_vec();
    let mut queue: Pending = VecDeque::new();
    let mut open = true;
    while open || !queue.is_empty() {
        let deadline = Instant::now() + policy.max_wait;
        while open && queue.len() < policy.max_batch {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Cmd::Serve(r, c)) => queue.push_back((r, c)),
                Ok(Cmd::Shutdown) => open = false,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if queue.is_empty() {
            continue;
        }
        let plan = decompose(queue.len(), &sizes);
        for launch in plan {
            if queue.is_empty() {
                break;
            }
            launch_group(engine.as_mut(), &mut queue, launch)?;
        }
    }
    Ok(())
}

/// Closed-loop demo against the PJRT backend: Poisson arrivals at `rate`
/// req/s, `total` requests, returns the metrics. Requires artifacts.
pub fn run_demo_metrics(
    dir: &Path,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
) -> Result<Metrics> {
    run_demo_metrics_observed(dir, total, rate, policy, None)
}

/// [`run_demo_metrics`] with a live [`MetricsHub`] for the scrape
/// endpoint (updated per response, not just at the end of the run).
pub fn run_demo_metrics_observed(
    dir: &Path,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
    hub: Option<Arc<MetricsHub>>,
) -> Result<Metrics> {
    // image size from the manifest (all serving artifacts share it)
    let manifest = crate::runtime::Manifest::load(dir)?;
    let (_, info) = manifest
        .artifacts
        .iter()
        .find(|(_, a)| a.kind == "swin_float")
        .context("no serving artifact")?;
    let img_len = info.inputs[0].numel() / info.batch.unwrap_or(1);
    let server = Server::start(dir, policy)?;
    drive(server, img_len, total, rate, hub)
}

/// Closed-loop demo against a simulated card: no artifacts needed.
pub fn run_demo_metrics_sim(
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    time_scale: f64,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
) -> Result<Metrics> {
    run_demo_metrics_sim_observed(variant, cfg, time_scale, total, rate, policy, None)
}

/// [`run_demo_metrics_sim`] with a live [`MetricsHub`] for the scrape
/// endpoint.
#[allow(clippy::too_many_arguments)]
pub fn run_demo_metrics_sim_observed(
    variant: &'static SwinVariant,
    cfg: AccelConfig,
    time_scale: f64,
    total: usize,
    rate: f64,
    policy: BatchPolicy,
    hub: Option<Arc<MetricsHub>>,
) -> Result<Metrics> {
    let img_len = variant.img_size * variant.img_size * variant.in_chans;
    let server = Server::start_sim(variant, cfg, time_scale, policy)?;
    drive(server, img_len, total, rate, hub)
}

/// Drive a server with Poisson arrivals and collect the metrics.
fn drive(
    server: Server,
    img_len: usize,
    total: usize,
    rate: f64,
    hub: Option<Arc<MetricsHub>>,
) -> Result<Metrics> {
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    let mut rng = Rng::new(7);
    let mut metrics = Metrics::default();
    let mut admitted = 0usize;
    let t0 = Instant::now();
    for id in 0..total {
        let image: Vec<f32> = (0..img_len).map(|_| rng.range_f32(0.0, 1.0)).collect();
        if server.submit(
            Request {
                id: id as u64,
                image,
                enqueued: Instant::now(),
            },
            resp_tx.clone(),
        )? {
            admitted += 1;
        }
        let gap = rng.exp(1.0 / rate);
        thread::sleep(Duration::from_secs_f64(gap.min(0.1)));
    }
    drop(resp_tx);
    for resp in resp_rx.iter() {
        metrics.record(&resp);
        if let Some(h) = &hub {
            h.record(&resp);
        }
        if metrics.completed as usize == admitted {
            break;
        }
    }
    metrics.wall = t0.elapsed();
    metrics.shed = server.shed_count();
    if let Some(h) = &hub {
        h.finish(metrics.shed, metrics.wall);
    }
    server.shutdown()?;
    Ok(metrics)
}

/// String-summary wrapper for the CLI.
pub fn run_demo(dir: &Path, total: usize, rate: f64, max_batch: usize) -> Result<String> {
    let policy = BatchPolicy {
        max_batch,
        ..Default::default()
    };
    Ok(run_demo_metrics(dir, total, rate, policy)?.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decompose_greedy_largest_fit() {
        let sizes = [8usize, 4, 2, 1];
        assert_eq!(decompose(8, &sizes), vec![8]);
        assert_eq!(decompose(7, &sizes), vec![4, 2, 1]);
        assert_eq!(decompose(13, &sizes), vec![8, 4, 1]);
        assert_eq!(decompose(1, &sizes), vec![1]);
    }

    #[test]
    fn decompose_pads_below_minimum() {
        let sizes = [8usize, 4];
        // 3 requests with a min engine of 4: run one padded batch of 4
        assert_eq!(decompose(3, &sizes), vec![4]);
    }

    #[test]
    fn pick_launch_largest_fit_or_pad() {
        let sizes = [8usize, 4, 2, 1];
        assert_eq!(pick_launch(13, &sizes), 8);
        assert_eq!(pick_launch(8, &sizes), 8);
        assert_eq!(pick_launch(5, &sizes), 4);
        assert_eq!(pick_launch(1, &sizes), 1);
        // below the smallest bucket: pad up to it
        assert_eq!(pick_launch(3, &[8, 4]), 4);
    }

    #[test]
    fn metrics_percentiles() {
        let m = Metrics {
            completed: 4,
            latencies_ms: vec![1.0, 2.0, 3.0, 100.0],
            wall: Duration::from_secs(1),
            ..Default::default()
        };
        assert!((m.percentile_ms(0.5) - 2.0).abs() < 1.01);
        assert!(m.percentile_ms(0.99) >= 3.0);
        assert!((m.throughput() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_occupancy_and_depth() {
        let mut m = Metrics::default();
        m.record(&Response {
            id: 0,
            logits: vec![],
            latency: Duration::from_millis(1),
            batch: 8,
            occupancy: 6,
            queue_depth: 11,
        });
        m.record(&Response {
            id: 1,
            logits: vec![],
            latency: Duration::from_millis(2),
            batch: 4,
            occupancy: 4,
            queue_depth: 3,
        });
        assert!((m.occupancy_mean() - (0.75 + 1.0) / 2.0).abs() < 1e-12);
        assert_eq!(m.queue_depth_max(), 11);
        assert_eq!(m.batches[&8], 1);
        assert_eq!(m.batches[&4], 1);
    }
}
